//! Per-lane batched sampling engine with step-granularity continuous
//! admission.
//!
//! SADA's stability criterion is *per-trajectory* (Criterion 3.4): different
//! prompts stabilize at different times, so a batched sampler that computes
//! one criterion over the concatenated batch forces a single global
//! skip/keep decision on every request — the failure mode AdaDiff attributes
//! to fixed per-prompt budgets. This module replaces that lockstep loop with
//! a **lane engine**: each request in a batch owns a *lane* with its own
//! accelerator instance (via [`Accelerator::clone_fresh`]), its own solver
//! multistep history, and its own [`RunStats`]. Every step:
//!
//! 1. each lane plans independently;
//! 2. lanes planning a model-executing mode (Full, Shallow, Prune) are
//!    gathered row-wise ([`crate::tensor::view::copy_into_row`]) into
//!    arena-pooled bucket buffers and executed through the largest
//!    fitting compiled `{base}_b{n}` bucket
//!    ([`crate::runtime::manifest::split_into_buckets`]), grouped by
//!    *variant signature*: kind, guidance scalar, timestep and — for
//!    Prune — the keep mask (a compiled variant takes one `gs`, one `t`
//!    and one mask input); oversized gathers split across several bucket
//!    launches plus batch-1 singles, so **no compiled bucket of the
//!    exact batch size is ever required**;
//! 3. model outputs are scattered back and every lane advances through its
//!    own solver; skipping lanes extrapolate lane-locally (AM-3 /
//!    Lagrange, Thm 3.5–3.7) at zero model cost — a skipping lane drops
//!    out of the model call entirely, shrinking the executed batch.
//!
//! **Degraded-variant buckets.** Shallow and Prune lanes batch exactly
//! like Full lanes: each variant-signature group chunks across its base
//! variant's compiled `shallow_b{n}` / `prune{k}_b{n}` buckets. Batched
//! aux layouts are batch-major and per-lane sliceable — a bucketed
//! launch gathers each lane's deep/cache features row-wise from its
//! retained [`crate::tensor::arena::AuxSlot`]s and scatters any
//! refreshed aux rows (and, for Full, the captured features) straight
//! back into them — so row k of every bucketed launch is bit-identical
//! to the lane's single launch and no per-step discount or capture is
//! traded away for batching. On a backend with no compiled buckets every
//! group degenerates to singles and the engine is feature-equivalent —
//! and bit-identical — to per-request sequential generation.
//!
//! **Continuous batching.** The engine core ([`Pipeline::generate_continuous`])
//! runs a fixed number of *slots* rather than a fixed batch: lanes join and
//! leave a running engine at step granularity. Every step the engine offers
//! its free slots to a caller-supplied [`LaneFeeder`]; admitted requests
//! start stepping on the very next engine step, and a lane's result is
//! handed back through [`LaneFeeder::complete`] the step it finishes — the
//! freed slot is offered for re-admission on the following step, so no slot
//! idles while the feeder has queued work. Because every lane's state is
//! private (own solver grid, own step index, own accelerator), admission
//! timing cannot perturb any other lane, and each lane's output is
//! **bit-identical to its solo [`Pipeline::generate`] run regardless of
//! when it was admitted** (property-tested below and in
//! `tests/arena_properties.rs`). Lanes need not share a step count: the
//! fewest-launches bucket split is re-run over the *live* lane set each
//! step, with the `(guidance, t)` group key keeping compiled-variant
//! scalar inputs exact. Admission into a previously-used slot reuses every
//! lane buffer in place (state re-drawn via [`Tensor::fill_from_rng`],
//! aux slots re-ensured against the arena) — an O(1) per-event cost that
//! never touches the steady-state zero-allocation discipline.
//! [`Pipeline::generate_lanes`] is now a thin wrapper: a one-shot feeder
//! that admits the whole batch into `reqs.len()` slots and collects
//! results in request order.
//!
//! **CacheWarm lanes.** A lane replaying a verified cached plan with
//! token-pruned (or shallow) directives signals the fresh step feeding
//! those directives via [`Accelerator::wants_aux_capture`]. Capture
//! steps gather like any other full step: a bucketed full launch
//! scatters each row's captured aux features into that lane's own
//! retained [`crate::tensor::arena::AuxSlot`]s (multi-row capture),
//! after which Prune directives replay natively — no `caches`-missing
//! degradation — with each pruned step refreshing its caches row through
//! the batched `prune{k}_b{n}` scatter (or an arena-pooled single).
//! Warm replays keep the NFE cut, the co-scheduled bucket throughput
//! *and* batched capture.
//!
//! With [`super::NoAccel`] the engine is bit-identical to sequential
//! [`Pipeline::generate`] per request (property-tested below): single-lane
//! chunks share the exact code path, and bucketed chunks are pure
//! gather/compute/scatter.
//!
//! **Memory discipline.** The step loop is zero-allocation at steady
//! state (pinned by `tests/zero_alloc.rs`): every lane owns reusable step
//! buffers (state, model output, data prediction, gradient) written
//! through the solvers' `_into` kernels and [`ModelBackend::run_into`];
//! bucket gathers write lane rows directly into buffers checked out from
//! the pipeline's [`crate::tensor::arena::TensorArena`] (released after
//! the scatter); and the per-step bookkeeping (plans, guidance groups,
//! bucket splits) lives in vectors allocated once before the loop.
//! Admission and completion are bounded per-event costs (solver grid,
//! stats vector, result assembly), never per-step ones.

use std::time::Instant;

use anyhow::Result;

use super::{
    apply_structural_fallbacks, Accelerator, GenRequest, GenResult, Pipeline, RunStats, StepCtx,
    StepMode, StepObs, StepPlan,
};
use crate::obs::PhaseAccum;
use crate::runtime::manifest::split_into_buckets;
use crate::runtime::{ModelArgs, ModelBackend, ModelInfo};
use crate::solvers::{build_solver, Solver};
use crate::tensor::arena::AuxSlot;
use crate::tensor::{view, Tensor};

/// Makers of fresh per-lane accelerator instances.
pub trait AcceleratorFactory {
    /// Build the accelerator for lane index `lane`.
    fn make(&self, lane: usize) -> Box<dyn Accelerator>;
}

/// Any accelerator prototype is the factory for its own lane copies.
impl AcceleratorFactory for dyn Accelerator {
    fn make(&self, _lane: usize) -> Box<dyn Accelerator> {
        self.clone_fresh()
    }
}

/// Adapter: build per-lane accelerators from a closure (heterogeneous
/// lane configurations, test harnesses).
pub struct FnFactory<F>(pub F);

impl<F: Fn(usize) -> Box<dyn Accelerator>> AcceleratorFactory for FnFactory<F> {
    fn make(&self, lane: usize) -> Box<dyn Accelerator> {
        (self.0)(lane)
    }
}

/// Execution discipline of the lane engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneMode {
    /// Every lane plans and executes independently (the SADA-faithful
    /// default).
    PerLane,
    /// Global-decision arm for per-lane-vs-lockstep sweeps: whenever any
    /// lane needs a fresh execution, every lane executes. This models the
    /// *regime* the retired lockstep batch path imposed — one skip/keep
    /// decision for the whole batch — not its exact implementation (which
    /// evaluated a single criterion over the concatenated tensor and
    /// required a compiled bucket of the exact batch size).
    Lockstep,
}

/// One request admitted into the continuous engine: the request itself, a
/// fresh accelerator instance for its lane, and a caller-chosen `tag`
/// echoed back verbatim through [`LaneFeeder::complete`].
pub struct AdmittedLane {
    pub req: GenRequest,
    pub accel: Box<dyn Accelerator>,
    pub tag: u64,
}

/// Live-lane snapshot offered to [`LaneFeeder::plan_preemptions`] each
/// engine step: enough to rank preemption victims without touching lane
/// internals.
#[derive(Clone, Copy, Debug)]
pub struct LaneStatus {
    pub tag: u64,
    /// The occupant's own step index (progress so far).
    pub step: usize,
    pub steps: usize,
    /// Whether the lane is replaying a verified cached plan
    /// ([`Accelerator::plan_key`] is `Some`) — the cheap-to-pause signal:
    /// a replaying lane's remaining cost is known and it re-verifies every
    /// replayed decision, so pausing it can never change its output.
    pub replaying: bool,
}

/// A preempted lane, frozen mid-run: everything needed to resume it —
/// possibly into a different slot, possibly many engine steps later —
/// with bit-identical results. The live tensors (`x`, `last_out`) move
/// into arena-checked-out buffers and the solver/accelerator state moves
/// wholesale, so a checkpoint is a bounded per-event cost, never a copy
/// of the whole lane history. Opaque by design: feeders park and return
/// checkpoints, only the engine opens them.
pub struct LaneCheckpoint {
    tag: u64,
    step: usize,
    steps: usize,
    req: GenRequest,
    solver: Box<dyn Solver>,
    accel: Box<dyn Accelerator>,
    wants_obs: bool,
    x: Tensor,
    last_out: Tensor,
    has_last: bool,
    deep: AuxSlot,
    caches: AuxSlot,
    stats: RunStats,
    timer: crate::report::Timer,
}

impl LaneCheckpoint {
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Step index the lane will resume at.
    pub fn step(&self) -> usize {
        self.step
    }

    pub fn steps(&self) -> usize {
        self.steps
    }
}

/// The continuous engine's request source and result sink.
///
/// `admit(free)` is called once per engine step while `free > 0` slots are
/// idle (including before the first step) and may return up to `free`
/// lanes to admit; returning an empty vector leaves the slots idle for
/// this step. The engine stops when every slot is idle and `admit` returns
/// nothing. `complete(tag, result)` delivers a lane's result the step it
/// finishes — its slot is offered back to `admit` on the next step.
///
/// The three preemption hooks are optional (defaults make the engine
/// preemption-free). Each engine step, before admission, the feeder sees
/// every active lane through `plan_preemptions` and may name victims by
/// tag; each victim is checkpointed ([`LaneCheckpoint`]) and handed back
/// through `preempted`, and its freed slot is offered to `admit` in the
/// same step. `resume(free)` runs after `admit` each step — urgent new
/// work outranks parked work — and may return previously-parked
/// checkpoints to re-install. A feeder must eventually return every
/// checkpoint it parked: the engine stops when all slots are idle and
/// both `admit` and `resume` come back empty, and any checkpoint still
/// parked at that point never completes.
pub trait LaneFeeder {
    fn admit(&mut self, free: usize) -> Vec<AdmittedLane>;
    fn complete(&mut self, tag: u64, result: GenResult);
    /// Name lanes to checkpoint this step as `(tag, slack_ms)` — the
    /// slack is echoed into the flight-recorder `Preempt` event. Unknown
    /// tags are ignored. Default: never preempt (and `Vec::new()` does
    /// not allocate, so the default keeps steady-state steps alloc-free).
    fn plan_preemptions(&mut self, _lanes: &[LaneStatus]) -> Vec<(u64, f64)> {
        Vec::new()
    }
    /// Take ownership of a checkpoint produced by `plan_preemptions`.
    fn preempted(&mut self, _ckpt: LaneCheckpoint) {}
    /// Return up to `free` parked checkpoints to resume, each with the
    /// slack to echo into the `Resume` event.
    fn resume(&mut self, _free: usize) -> Vec<(LaneCheckpoint, f64)> {
        Vec::new()
    }
}

/// Occupancy accounting for one continuous-engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ContinuousStats {
    /// Engine steps executed (each step advances every active lane once).
    pub steps: usize,
    /// Sum over steps of the number of active lanes (useful work).
    pub lane_steps: usize,
    /// Sum over steps of the slot count (`steps * capacity`).
    pub slot_steps: usize,
    /// Lanes admitted over the run.
    pub admitted: usize,
    /// Lanes completed over the run (equals `admitted` on clean exit —
    /// a preempt/resume cycle completes its lane exactly once).
    pub completed: usize,
    /// Preemption checkpoints taken over the run.
    pub preempted: usize,
    /// Checkpoints resumed back into slots over the run.
    pub resumed: usize,
    /// Wall-clock time of the whole engine run.
    pub wall_ms: f64,
}

impl ContinuousStats {
    /// Mean bucket occupancy: fraction of slot-steps that carried an
    /// active lane. 1.0 means no slot ever idled while the engine ran.
    pub fn occupancy(&self) -> f64 {
        self.lane_steps as f64 / self.slot_steps.max(1) as f64
    }
}

/// One slot's private lane state, with its reusable step buffers (the
/// zero-allocation discipline: buffers are written in place every step and
/// swapped, never reallocated; admission into a used slot refills them in
/// place).
struct Lane {
    /// Whether this slot currently carries a live request.
    active: bool,
    /// Feeder-chosen identity of the current occupant.
    tag: u64,
    /// The occupant's own step index (lanes need not be step-aligned).
    step: usize,
    /// The occupant's total step count.
    steps: usize,
    req: GenRequest,
    solver: Box<dyn Solver>,
    accel: Box<dyn Accelerator>,
    wants_obs: bool,
    /// Current state x_i (swapped with `x_next` after every step).
    x: Tensor,
    x_next: Tensor,
    /// This step's model output (swapped with `last_out` after the step).
    m_out: Tensor,
    last_out: Tensor,
    has_last: bool,
    /// Whether `m_out` holds a fresh execution for the current step.
    executed: bool,
    x0: Tensor,
    y: Tensor,
    /// Persistent model args: `x` slot copied in place per call, cond
    /// buffer reused across occupants when shapes match.
    args: ModelArgs,
    /// DeepCache deep feature from this lane's last full run — filled in
    /// place by a single, or scattered per row from a bucketed launch's
    /// batch-major aux output into this retained, arena-sourced buffer.
    deep: AuxSlot,
    /// Attention caches from this lane's last full/prune run (same
    /// retained-slot discipline, same single-or-scattered refresh).
    caches: AuxSlot,
    stats: RunStats,
    /// Started at admission: per-lane wall time, not engine wall time.
    timer: crate::report::Timer,
}

/// Compiled-bucket planning state for one batchable base variant, built
/// once per engine run: the `{base}_b{n}` bucket sizes resolved through
/// [`ModelInfo::variant_buckets`], the fewest-launches split for every
/// possible gather size, and the bucket variant names.
struct VariantTable {
    /// Batch-1 base variant this table batches ("full", "shallow", or a
    /// prune bucket variant like "prune50").
    base: String,
    /// `splits[n]` = fewest-launches chunk plan for an n-lane gather
    /// (all-singles when the base has no compiled buckets).
    splits: Vec<Vec<usize>>,
    /// Compiled `{base}_b{n}` variant names per bucket size, built once.
    variants: Vec<(usize, String)>,
}

impl VariantTable {
    fn build(info: &ModelInfo, base: &str, capacity: usize) -> Self {
        let buckets = info.variant_buckets(base);
        Self {
            base: base.to_string(),
            splits: (0..=capacity).map(|n| split_into_buckets(n, &buckets)).collect(),
            variants: buckets
                .iter()
                .map(|&n| (n, ModelInfo::variant_for(base, n)))
                .collect(),
        }
    }
}

/// Collision guard for the fingerprint-keyed Prune groups: two plans may
/// share a bucket launch only when their keep masks are actually *equal*,
/// not merely hash-equal. Non-Prune plans trivially agree (their group
/// key carries no mask).
fn same_mask(a: &StepPlan, b: &StepPlan) -> bool {
    match (a, b) {
        (StepPlan::Prune { mask: ma }, StepPlan::Prune { mask: mb }) => {
            std::sync::Arc::ptr_eq(ma, mb) || **ma == **mb
        }
        _ => true,
    }
}

/// Step-loop bookkeeping allocated once per engine run and reused every
/// step (cleared, never reallocated at steady state).
struct LaneScratch {
    /// Per-step plans, slot-indexed (inactive slots hold an inert
    /// placeholder that every consumer skips).
    plans: Vec<StepPlan>,
    /// Execution groups keyed by variant signature: `(kind, guidance
    /// bits, t_norm bits, keep-mask fingerprint)` — a compiled variant
    /// takes one `gs`, one `t` (and, for prune buckets, one mask) input,
    /// so only lanes agreeing on all of them may gather. Parallel
    /// key/member vectors in first-appearance order; member vectors are
    /// recycled across steps.
    group_keys: Vec<(u8, u32, u64, u64)>,
    group_members: Vec<Vec<usize>>,
    /// Per-group partition of members into forced singles
    /// (edge-conditioned lanes, mask-collision stragglers) and batchable
    /// lanes.
    singles: Vec<usize>,
    batchable: Vec<usize>,
    /// One bucket table per batchable base variant — "full", "shallow"
    /// and each compiled prune bucket. The variant-signature groups in
    /// [`Pipeline::execute_planned_lanes`] resolve into these.
    tables: Vec<VariantTable>,
    /// Per-engine-step phase timers for the flight recorder
    /// ([`crate::obs`]). Disabled (every mark a no-op) unless a trace
    /// session is live, so untraced runs never touch the clock.
    phase: PhaseAccum,
}

/// One-shot feeder behind [`Pipeline::generate_lanes`]: admits the whole
/// batch on the first offer and collects results by request index.
struct CollectFeeder {
    pending: Vec<AdmittedLane>,
    results: Vec<Option<GenResult>>,
}

impl LaneFeeder for CollectFeeder {
    fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
        let n = free.min(self.pending.len());
        // xtask: allow(alloc): per-batch admission handoff, not a step cost
        self.pending.drain(..n).collect()
    }

    fn complete(&mut self, tag: u64, result: GenResult) {
        if let Some(slot) = self.results.get_mut(tag as usize) {
            *slot = Some(result);
        }
    }
}

impl<'a, B: ModelBackend> Pipeline<'a, B> {
    /// Run a batch of requests through the per-lane engine. Requests must
    /// share a step count; seeds, conds, guidance and edges may differ
    /// (mixed-guidance lanes execute in separate sub-batches).
    pub fn generate_lanes<F: AcceleratorFactory + ?Sized>(
        &self,
        reqs: &[GenRequest],
        factory: &F,
    ) -> Result<Vec<GenResult>> {
        self.generate_lanes_mode(reqs, factory, LaneMode::PerLane)
    }

    /// [`Pipeline::generate_lanes`] with an explicit [`LaneMode`].
    pub fn generate_lanes_mode<F: AcceleratorFactory + ?Sized>(
        &self,
        reqs: &[GenRequest],
        factory: &F,
        mode: LaneMode,
    ) -> Result<Vec<GenResult>> {
        anyhow::ensure!(!reqs.is_empty(), "empty batch");
        let steps = reqs[0].steps;
        anyhow::ensure!(
            reqs.iter().all(|r| r.steps == steps),
            "lane batch must share step count"
        );
        // xtask: allow(alloc, begin): per-batch wrapper assembly — the
        // one-shot feeder and its request copies are built once per call
        let mut feeder = CollectFeeder {
            pending: reqs
                .iter()
                .enumerate()
                .map(|(li, req)| AdmittedLane {
                    req: req.clone(),
                    accel: factory.make(li),
                    tag: li as u64,
                })
                .collect(),
            results: (0..reqs.len()).map(|_| None).collect(),
        };
        // xtask: allow(alloc, end)
        self.run_continuous(reqs.len(), &mut feeder, mode)?;
        // xtask: allow(alloc): per-batch result assembly, once per call
        feeder
            .results
            .into_iter()
            .enumerate()
            .map(|(k, r)| r.ok_or_else(|| anyhow::anyhow!("lane {k} produced no result")))
            .collect()
    }

    /// Run the continuous-batching engine: `capacity` slots, fed at step
    /// granularity by `feeder` (see [`LaneFeeder`] for the admission
    /// contract). Returns occupancy accounting; per-lane results flow
    /// through [`LaneFeeder::complete`] as lanes finish.
    pub fn generate_continuous<F: LaneFeeder + ?Sized>(
        &self,
        capacity: usize,
        feeder: &mut F,
    ) -> Result<ContinuousStats> {
        self.run_continuous(capacity, feeder, LaneMode::PerLane)
    }

    /// The engine core shared by [`Pipeline::generate_continuous`] and the
    /// fixed-batch wrappers.
    fn run_continuous<F: LaneFeeder + ?Sized>(
        &self,
        capacity: usize,
        feeder: &mut F,
        mode: LaneMode,
    ) -> Result<ContinuousStats> {
        anyhow::ensure!(capacity > 0, "continuous engine needs at least one slot");
        // xtask: allow(alloc, begin): engine init — the slot vector, bucket
        // split tables and step bookkeeping are allocated once here; the
        // per-step loop below reuses them in place
        let info = self.backend.info().clone();
        // one bucket table per batchable base variant: full, shallow and
        // each compiled prune bucket (kind + keep-count bucket is the
        // variant signature the execution groups key on)
        let mut tables: Vec<VariantTable> =
            Vec::with_capacity(2 + info.prune_variants().len());
        tables.push(VariantTable::build(&info, "full", capacity));
        tables.push(VariantTable::build(&info, "shallow", capacity));
        for (base, _) in info.prune_variants() {
            tables.push(VariantTable::build(&info, base, capacity));
        }
        // trace session checkout: per-lane ring buffers are preallocated
        // here so the step loop records without allocating (None when no
        // recorder is attached or sampling is Off — every recording branch
        // below is then dead)
        let mut sess = self
            .recorder
            .as_ref()
            .and_then(|(rec, worker)| rec.begin_session(*worker, capacity));
        let mut lanes: Vec<Lane> = Vec::with_capacity(capacity);
        let mut sc = LaneScratch {
            plans: Vec::with_capacity(capacity),
            group_keys: Vec::with_capacity(capacity),
            group_members: Vec::new(),
            singles: Vec::with_capacity(capacity),
            batchable: Vec::with_capacity(capacity),
            tables,
            phase: PhaseAccum::for_session(sess.is_some()),
        };
        let mut stats = ContinuousStats::default();
        let mut statuses: Vec<LaneStatus> = Vec::with_capacity(capacity);
        // xtask: allow(alloc, end)

        let timer = crate::report::Timer::start();
        loop {
            let mut active = lanes.iter().filter(|l| l.active).count();
            // preemption: before admission, the feeder sees every live
            // lane and may checkpoint victims — their slots are offered to
            // `admit` immediately below, so an urgent queued request takes
            // over a preempted slot within the same engine step. The
            // status scan reuses its scratch vector and the default hook
            // returns an unallocated empty Vec, so a preemption-free run
            // pays nothing here at steady state.
            if active > 0 {
                statuses.clear();
                for lane in lanes.iter() {
                    if lane.active {
                        statuses.push(LaneStatus {
                            tag: lane.tag,
                            step: lane.step,
                            steps: lane.steps,
                            replaying: lane.accel.plan_key().is_some(),
                        });
                    }
                }
                // xtask: allow(alloc, begin): preemption event — bounded
                // per-victim cost (checkpoint assembly, feeder handoff),
                // never a steady-state step cost
                for (tag, slack_ms) in feeder.plan_preemptions(&statuses) {
                    let Some(s) = lanes.iter().position(|l| l.active && l.tag == tag)
                    else {
                        continue;
                    };
                    if let Some(sess) = sess.as_mut() {
                        if sess.records_lane(tag) {
                            let t_us = sess.now_us();
                            sess.record_preempt(s, tag, lanes[s].step as u32, slack_ms, t_us);
                        }
                    }
                    let ckpt = self.checkpoint_lane(&mut lanes[s]);
                    feeder.preempted(ckpt);
                    stats.preempted += 1;
                    active -= 1;
                }
                // xtask: allow(alloc, end)
            }
            // admission: every step with idle slots offers them to the
            // feeder; admitted lanes step starting this engine step
            if active < capacity {
                // xtask: allow(alloc, begin): admission event — bounded
                // per-admitted-lane cost (solver grid, stats vector, feeder
                // handoff), never a steady-state step cost
                let admitted = feeder.admit(capacity - active);
                anyhow::ensure!(
                    admitted.len() <= capacity - active,
                    "feeder admitted {} lanes into {} free slots",
                    admitted.len(),
                    capacity - active
                );
                for a in admitted {
                    let tag = a.tag;
                    let slot = self.admit_lane(&mut lanes, capacity, &info, a)?;
                    if let Some(s) = sess.as_mut() {
                        if s.records_lane(tag) {
                            let t_us = s.now_us();
                            s.record_admit(slot, tag, t_us);
                        }
                    }
                    stats.admitted += 1;
                    active += 1;
                }
                // xtask: allow(alloc, end)
            }
            // resume: parked checkpoints fill whatever slots fresh
            // admission left idle (new urgent work outranks parked work)
            if active < capacity {
                // xtask: allow(alloc, begin): resume event — bounded
                // per-checkpoint cost mirroring admission
                let resumed = feeder.resume(capacity - active);
                anyhow::ensure!(
                    resumed.len() <= capacity - active,
                    "feeder resumed {} lanes into {} free slots",
                    resumed.len(),
                    capacity - active
                );
                for (c, slack_ms) in resumed {
                    let (tag, step) = (c.tag, c.step);
                    let slot = self.restore_lane(&mut lanes, capacity, c)?;
                    if let Some(s) = sess.as_mut() {
                        if s.records_lane(tag) {
                            let t_us = s.now_us();
                            s.record_resume(slot, tag, step as u32, slack_ms, t_us);
                        }
                    }
                    stats.resumed += 1;
                    active += 1;
                }
                // xtask: allow(alloc, end)
            }
            if active == 0 {
                break;
            }
            stats.steps += 1;
            stats.lane_steps += active;
            stats.slot_steps += capacity;

            // 1) every active lane plans independently from its own history
            sc.plans.clear();
            for lane in lanes.iter_mut() {
                if !lane.active {
                    // inert placeholder keeps sc.plans slot-indexed; every
                    // consumer below skips inactive slots
                    sc.plans.push(StepPlan::Full);
                    continue;
                }
                let ctx = StepCtx {
                    i: lane.step,
                    n_steps: lane.steps,
                    x: &lane.x,
                    t_norm: lane.solver.t_norm(lane.step),
                    have_caches: lane.caches.is_valid(),
                    have_deep: lane.deep.is_valid(),
                };
                let planned = lane.accel.plan(&ctx);
                // structural fallbacks: the shared rule owns the warm/cold
                // decision (same contract as Pipeline::generate)
                let (plan, degraded) = apply_structural_fallbacks(
                    planned,
                    lane.deep.is_valid(),
                    lane.caches.is_valid(),
                    lane.has_last,
                );
                if let Some(mode) = degraded {
                    lane.stats.record_degraded(mode);
                }
                sc.plans.push(plan);
            }
            if mode == LaneMode::Lockstep
                && lanes.iter().zip(sc.plans.iter()).any(|(lane, p)| {
                    lane.active
                        && !matches!(
                            p,
                            StepPlan::SkipReuse
                                | StepPlan::SkipExtrapolate
                                | StepPlan::SkipLagrange
                        )
                })
            {
                for (lane, p) in lanes.iter().zip(sc.plans.iter_mut()) {
                    if lane.active {
                        *p = StepPlan::Full;
                    }
                }
            }

            // 2) execute: every model-executing lane gathered bucket-aware
            //    into arena buffers by variant signature (full, shallow and
            //    prune buckets alike)
            for lane in lanes.iter_mut() {
                lane.executed = false;
            }
            self.execute_planned_lanes(&mut lanes, &mut sc)?;

            // 3) every active lane advances through its own solver +
            // accelerator. The arms below mirror Pipeline::generate's step
            // body — keep the two in lockstep (the NoAccel/DeepCache
            // bit-identity property tests pin the executed paths against
            // drift).
            let mut t_solver = sc.phase.mark();
            for (l, lane) in lanes.iter_mut().enumerate() {
                if !lane.active {
                    continue;
                }
                let plan = &sc.plans[l];
                let i = lane.step;
                let t_norm = lane.solver.t_norm(i);
                let fresh = lane.executed;
                let step_t0 = match sess.as_ref() {
                    Some(s) if s.records_lane(lane.tag) => Some(Instant::now()),
                    _ => None,
                };
                match plan {
                    StepPlan::Full | StepPlan::Shallow | StepPlan::Prune { .. } => {
                        anyhow::ensure!(lane.executed, "executed lane lost its output");
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                    StepPlan::SkipReuse => {
                        anyhow::ensure!(lane.has_last, "SkipReuse without history");
                        lane.m_out.copy_from(&lane.last_out);
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                    StepPlan::SkipExtrapolate => {
                        anyhow::ensure!(lane.has_last, "SkipExtrapolate without history");
                        lane.m_out.copy_from(&lane.last_out);
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.gradient_into(&lane.x, &lane.m_out, i, &mut lane.y);
                        let dt = lane.solver.dt(i);
                        if !lane.accel.extrapolate_into(&lane.x, &lane.y, dt, &mut lane.x_next) {
                            crate::tensor::ops::lincomb2_into(
                                1.0,
                                &lane.x,
                                -(dt as f32),
                                &lane.y,
                                &mut lane.x_next,
                            );
                        }
                        lane.solver.inject_x0(&lane.x0, i);
                    }
                    StepPlan::SkipLagrange => {
                        anyhow::ensure!(
                            lane.accel.reconstruct_x0_into(t_norm, &mut lane.x0),
                            "SkipLagrange without a filled x0 buffer"
                        );
                        lane.solver.model_out_from_x0_into(&lane.x, &lane.x0, i, &mut lane.m_out);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                }
                if lane.wants_obs {
                    // the SkipExtrapolate arm already computed this
                    // gradient from the same inputs
                    if !matches!(plan, StepPlan::SkipExtrapolate) {
                        lane.solver.gradient_into(&lane.x, &lane.m_out, i, &mut lane.y);
                    }
                    let obs = StepObs {
                        i,
                        n_steps: lane.steps,
                        fresh,
                        x_prev: &lane.x,
                        x_next: &lane.x_next,
                        model_out: &lane.m_out,
                        x0: &lane.x0,
                        y: &lane.y,
                        dt: lane.solver.dt(i),
                        t_norm,
                    };
                    lane.accel.observe(&obs);
                }
                lane.stats.record_step(plan, fresh);
                if let (Some(s), Some(t0)) = (sess.as_mut(), step_t0) {
                    // the decision record: what this lane did at step i and
                    // what the criterion saw — ring push, no allocation
                    let t_us = s.rel_us(t0);
                    let dur_us = t0.elapsed().as_secs_f64() * 1e6;
                    s.record_step(
                        l,
                        lane.tag,
                        i as u32,
                        StepMode::from_plan(plan),
                        fresh,
                        lane.accel.last_criterion_dot(),
                        t_us,
                        dur_us,
                    );
                }
                std::mem::swap(&mut lane.m_out, &mut lane.last_out);
                lane.has_last = true;
                std::mem::swap(&mut lane.x, &mut lane.x_next);
                lane.step += 1;
                if lane.step == lane.steps {
                    // completion: hand the result to the feeder and free
                    // the slot — it is offered for re-admission on the
                    // next engine step. Aux buffers stay retained for the
                    // next occupant's in-place refill.
                    // xtask: allow(alloc, begin): completion event —
                    // result assembly is a per-run cost, not a step cost
                    let mut st =
                        std::mem::replace(&mut lane.stats, RunStats::new(String::new(), 0));
                    st.wall_ms = lane.timer.elapsed_ms();
                    st.nfe = st.fresh_steps;
                    st.outcome = lane.accel.outcome();
                    st.degraded.add(&lane.accel.planned_degradations());
                    if let Some(s) = sess.as_mut() {
                        if s.records_lane(lane.tag) {
                            let t_us = s.now_us();
                            s.record_complete(
                                l,
                                lane.tag,
                                st.outcome,
                                st.nfe as u32,
                                st.modes.len() as u32,
                                t_us,
                            );
                        }
                    }
                    feeder.complete(lane.tag, GenResult { image: lane.x.clone(), stats: st });
                    // xtask: allow(alloc, end)
                    lane.active = false;
                    stats.completed += 1;
                }
            }
            sc.phase.solver_us += PhaseAccum::lap(&mut t_solver);
            if let Some(s) = sess.as_mut() {
                // lay this engine step's phase spans onto the engine track
                // (ring pushes only) and reset the accumulators
                let end_us = s.now_us();
                s.flush_phases(&mut sc.phase, active as u32, end_us);
            }
        }

        stats.wall_ms = timer.elapsed_ms();
        // fold the finished trace session back into the recorder (a
        // per-run cost: one archive push under the recorder lock)
        if let Some(s) = sess.take() {
            if let Some((rec, _)) = self.recorder.as_ref() {
                rec.end_session(s);
            }
        }
        // aux buffers go back to the pool for the next engine run's lanes
        for lane in lanes.iter_mut() {
            lane.deep.retire(&self.arena);
            lane.caches.retire(&self.arena);
        }
        Ok(stats)
    }

    /// Place an admitted request into a slot, returning the slot index
    /// (the flight recorder's ring index for this occupant). The first
    /// inactive slot's buffers are reused in place (state re-drawn from
    /// the request seed, aux slots re-ensured against the arena — the
    /// O(1) admission contract); while the engine holds fewer slots than
    /// `capacity`, a fresh slot is allocated instead.
    // Admission is a bounded per-event cost (solver grid, stats vector,
    // cond clone on shape change, first-use slot allocation), never a
    // per-step one.
    // xtask: allow(alloc): per-admission-event cost, argued above
    fn admit_lane(
        &self,
        lanes: &mut Vec<Lane>,
        capacity: usize,
        info: &ModelInfo,
        a: AdmittedLane,
    ) -> Result<usize> {
        let AdmittedLane { req, mut accel, tag } = a;
        let steps = req.steps;
        anyhow::ensure!(steps > 0, "admitted lane needs at least one step");
        let [h, w, c] = info.img;
        let shape = [1usize, h, w, c];
        accel.reset();
        accel.begin_run(&req);
        let mut solver = build_solver(self.solver_kind, self.schedule(), steps);
        solver.reset();
        let wants_obs = accel.wants_obs();
        let stats = RunStats::new(accel.name(), steps);
        match lanes.iter_mut().position(|l| !l.active) {
            Some(s) => {
                // slot reuse: every tensor buffer is refilled in place
                let lane = &mut lanes[s];
                let mut rng = crate::rng::Rng::new(req.seed);
                lane.x.fill_from_rng(&mut rng);
                let cond = match lane.args.cond.take() {
                    Some(mut cbuf) if cbuf.same_shape(&req.cond) => {
                        cbuf.copy_from(&req.cond);
                        Some(cbuf)
                    }
                    _ => Some(req.cond.clone()),
                };
                // rebuild args around the retained buffers so no stale
                // per-occupant field (masks, aux handoffs) survives
                lane.args = ModelArgs {
                    x: lane.args.x.take(),
                    t: 0.0,
                    cond,
                    gs: req.guidance,
                    edge: req.edge.clone(),
                    ..Default::default()
                };
                lane.deep.ensure(&self.arena, &info.deep_shape());
                lane.caches.ensure(&self.arena, &info.caches_shape());
                lane.deep.invalidate();
                lane.caches.invalidate();
                lane.solver = solver;
                lane.accel = accel;
                lane.wants_obs = wants_obs;
                lane.stats = stats;
                lane.has_last = false;
                lane.executed = false;
                lane.step = 0;
                lane.steps = steps;
                lane.tag = tag;
                lane.active = true;
                lane.timer = crate::report::Timer::start();
                lane.req = req;
                Ok(s)
            }
            None => {
                anyhow::ensure!(lanes.len() < capacity, "no free slot for admitted lane");
                let mut rng = crate::rng::Rng::new(req.seed);
                let x = Tensor::from_rng(&mut rng, &shape);
                // aux slots hold arena buffers for the whole engine run
                // (retired at the end), so single captures refill in place
                let mut deep = AuxSlot::new();
                let mut caches = AuxSlot::new();
                deep.ensure(&self.arena, &info.deep_shape());
                caches.ensure(&self.arena, &info.caches_shape());
                lanes.push(Lane {
                    active: true,
                    tag,
                    step: 0,
                    steps,
                    solver,
                    accel,
                    wants_obs,
                    x,
                    x_next: Tensor::zeros(&shape),
                    m_out: Tensor::zeros(&shape),
                    last_out: Tensor::zeros(&shape),
                    has_last: false,
                    executed: false,
                    x0: Tensor::zeros(&shape),
                    y: Tensor::zeros(&shape),
                    args: ModelArgs {
                        x: Some(Tensor::zeros(&shape)),
                        t: 0.0,
                        cond: Some(req.cond.clone()),
                        gs: req.guidance,
                        edge: req.edge.clone(),
                        ..Default::default()
                    },
                    deep,
                    caches,
                    stats,
                    timer: crate::report::Timer::start(),
                    req,
                });
                Ok(lanes.len() - 1)
            }
        }
    }

    /// Freeze an active lane into a [`LaneCheckpoint`] and free its slot.
    ///
    /// Called between solver steps, a lane's live state is exactly: the
    /// current `x`, the previous model output (`last_out`/`has_last`), the
    /// solver's multistep history, the accelerator's run state, the
    /// retained aux slots, and the accumulated stats. The two live tensors
    /// are *swapped* with arena checkouts (no copies: the checkpoint keeps
    /// the originals, the slot gets standby buffers its next occupant
    /// fully overwrites) and everything else moves; scratch buffers
    /// (`x_next`, `m_out`, `x0`, `y`) are written before read every step
    /// and stay with the slot. Restoring the checkpoint therefore resumes
    /// the trajectory bit-identically — preemption can change *when* a
    /// lane steps, never *what* it computes.
    // Bounded per-preemption-event cost (one dummy solver grid + request,
    // two warm arena checkouts), never a per-step one.
    // xtask: allow(alloc): per-preemption-event cost, argued above
    fn checkpoint_lane(&self, lane: &mut Lane) -> LaneCheckpoint {
        let standby_x = self.arena.checkout(lane.x.shape());
        let standby_out = self.arena.checkout(lane.last_out.shape());
        let ckpt = LaneCheckpoint {
            tag: lane.tag,
            step: lane.step,
            steps: lane.steps,
            req: std::mem::replace(
                &mut lane.req,
                GenRequest {
                    cond: Tensor::zeros(&[1]),
                    seed: 0,
                    guidance: 0.0,
                    steps: 0,
                    edge: None,
                },
            ),
            solver: std::mem::replace(
                &mut lane.solver,
                build_solver(self.solver_kind, self.schedule(), 1),
            ),
            accel: std::mem::replace(&mut lane.accel, Box::new(super::NoAccel)),
            wants_obs: lane.wants_obs,
            x: std::mem::replace(&mut lane.x, standby_x),
            last_out: std::mem::replace(&mut lane.last_out, standby_out),
            has_last: lane.has_last,
            deep: std::mem::replace(&mut lane.deep, AuxSlot::new()),
            caches: std::mem::replace(&mut lane.caches, AuxSlot::new()),
            stats: std::mem::replace(&mut lane.stats, RunStats::new(String::new(), 0)),
            timer: lane.timer,
        };
        lane.active = false;
        lane.has_last = false;
        lane.executed = false;
        ckpt
    }

    /// Re-install a checkpointed lane into a free slot (the admission
    /// counterpart of [`Pipeline::checkpoint_lane`]): the checkpoint's
    /// live tensors swap back in, the slot's standby buffers return to the
    /// arena, and the moved solver/accelerator/aux state is installed
    /// untouched — no RNG re-draw, no accelerator reset, no aux
    /// invalidation, so the resumed lane continues exactly where it froze.
    // Bounded per-resume-event cost mirroring admission (cond clone on
    // shape change at worst), never a per-step one.
    // xtask: allow(alloc): per-resume-event cost, argued above
    fn restore_lane(
        &self,
        lanes: &mut Vec<Lane>,
        capacity: usize,
        c: LaneCheckpoint,
    ) -> Result<usize> {
        let LaneCheckpoint {
            tag,
            step,
            steps,
            req,
            solver,
            accel,
            wants_obs,
            x,
            last_out,
            has_last,
            deep,
            caches,
            stats,
            timer,
        } = c;
        match lanes.iter_mut().position(|l| !l.active) {
            Some(s) => {
                let lane = &mut lanes[s];
                // live tensors swap in; the slot's standby buffers pool
                self.arena.release(std::mem::replace(&mut lane.x, x));
                self.arena.release(std::mem::replace(&mut lane.last_out, last_out));
                // the slot's retained aux buffers go back to the pool and
                // the checkpoint's (validity bits intact) take their place
                let mut old_deep = std::mem::replace(&mut lane.deep, deep);
                let mut old_caches = std::mem::replace(&mut lane.caches, caches);
                old_deep.retire(&self.arena);
                old_caches.retire(&self.arena);
                let cond = match lane.args.cond.take() {
                    Some(mut cbuf) if cbuf.same_shape(&req.cond) => {
                        cbuf.copy_from(&req.cond);
                        Some(cbuf)
                    }
                    _ => Some(req.cond.clone()),
                };
                lane.args = ModelArgs {
                    x: lane.args.x.take(),
                    t: 0.0,
                    cond,
                    gs: req.guidance,
                    edge: req.edge.clone(),
                    ..Default::default()
                };
                lane.solver = solver;
                lane.accel = accel;
                lane.wants_obs = wants_obs;
                lane.stats = stats;
                lane.has_last = has_last;
                lane.executed = false;
                lane.step = step;
                lane.steps = steps;
                lane.tag = tag;
                lane.active = true;
                lane.timer = timer;
                lane.req = req;
                Ok(s)
            }
            None => {
                anyhow::ensure!(lanes.len() < capacity, "no free slot for resumed lane");
                let shape = x.shape().to_vec();
                lanes.push(Lane {
                    active: true,
                    tag,
                    step,
                    steps,
                    solver,
                    accel,
                    wants_obs,
                    x,
                    x_next: Tensor::zeros(&shape),
                    m_out: Tensor::zeros(&shape),
                    last_out,
                    has_last,
                    executed: false,
                    x0: Tensor::zeros(&shape),
                    y: Tensor::zeros(&shape),
                    args: ModelArgs {
                        x: Some(Tensor::zeros(&shape)),
                        t: 0.0,
                        cond: Some(req.cond.clone()),
                        gs: req.guidance,
                        edge: req.edge.clone(),
                        ..Default::default()
                    },
                    deep,
                    caches,
                    stats,
                    timer,
                    req,
                });
                Ok(lanes.len() - 1)
            }
        }
    }

    /// Execute every active lane whose plan needs the model this engine
    /// step, writing outputs into each lane's `m_out` buffer (`executed`
    /// marks success). Lanes are grouped by *variant signature* — kind
    /// (Full/Shallow/Prune), guidance, timestep and keep mask: a compiled
    /// variant takes one `gs`, one `t` (and one mask) input, and
    /// continuous lanes need not be step-aligned. Each group chunks
    /// across its base variant's compiled `{base}_b{n}` buckets through
    /// arena-pooled gather buffers; edge-conditioned lanes run as singles
    /// (edge inputs are only compiled for batch-1 variants). Every
    /// execution is classified into the lane's
    /// [`crate::pipeline::stats::ExecMix`], so the batched-vs-single
    /// split (and *why* a step ran single) is visible per run.
    fn execute_planned_lanes(&self, lanes: &mut [Lane], sc: &mut LaneScratch) -> Result<()> {
        let LaneScratch { plans, group_keys, group_members, singles, batchable, tables, phase } =
            sc;
        // group by variant signature, preserving lane order (reused
        // key/member vectors — no per-step allocation once every distinct
        // key has appeared)
        group_keys.clear();
        for members in group_members.iter_mut() {
            members.clear();
        }
        for (l, plan) in plans.iter().enumerate() {
            if !lanes[l].active {
                continue;
            }
            let (kind, mask_fp) = match plan {
                StepPlan::Full => (0u8, 0u64),
                StepPlan::Shallow => (1, 0),
                StepPlan::Prune { mask } => (2, mask.fingerprint()),
                _ => continue, // skip modes execute nothing
            };
            let key = (
                kind,
                lanes[l].req.guidance.to_bits(),
                lanes[l].solver.t_norm(lanes[l].step).to_bits(),
                mask_fp,
            );
            let gi = match group_keys.iter().position(|k| *k == key) {
                Some(gi) => gi,
                None => {
                    group_keys.push(key);
                    if group_members.len() < group_keys.len() {
                        // xtask: allow(alloc): grows only when a new distinct
                        // variant-signature key first appears, then is reused
                        group_members.push(Vec::new());
                    }
                    group_keys.len() - 1
                }
            };
            group_members[gi].push(l);
        }
        for gi in 0..group_keys.len() {
            let kind = group_keys[gi].0;
            // co-schedule lanes replaying the same verified cached plan
            // into the same bucket chunk: their fresh steps coincide for
            // the rest of the run, so keeping them adjacent maximizes
            // full-bucket gathers on later steps. Stable sort: unkeyed
            // lanes keep lane order (slices this short sort in place).
            group_members[gi].sort_by_key(|l| match lanes[*l].accel.plan_key() {
                Some(k) => (0u8, k),
                None => (1u8, 0),
            });
            let lead = group_members[gi][0];
            singles.clear();
            batchable.clear();
            for &l in group_members[gi].iter() {
                // forced singles: edge-conditioned lanes (edge inputs are
                // only compiled at batch 1), plus — fingerprints must never
                // merge different masks — Prune lanes whose mask is not
                // *equal* to the group lead's (collision guard; equal masks
                // are the overwhelmingly common case)
                if lanes[l].req.edge.is_some() || !same_mask(&plans[l], &plans[lead]) {
                    singles.push(l);
                } else {
                    batchable.push(l);
                }
            }
            for &l in singles.iter() {
                if kind == 0 {
                    self.run_lane_single(&mut lanes[l], phase)?;
                } else {
                    self.run_lane_degraded_single(&mut lanes[l], &plans[l], phase)?;
                }
                let lane = &mut lanes[l];
                if lane.req.edge.is_some() {
                    lane.stats.mix.single_edge += 1;
                } else {
                    lane.stats.mix.single_residue += 1;
                }
            }
            // resolve the group's bucket table: Full and Shallow tables
            // exist for every backend; a Prune group uses its mask
            // variant's table
            let ti = match kind {
                0 => tables.iter().position(|t| t.base == "full"),
                1 => tables.iter().position(|t| t.base == "shallow"),
                _ => match &plans[lead] {
                    StepPlan::Prune { mask } => {
                        tables.iter().position(|t| t.base == mask.variant)
                    }
                    _ => None,
                },
            };
            let table = match ti {
                Some(ti) => &tables[ti],
                None => {
                    // a mask variant with no bucket table: all singles
                    for &l in batchable.iter() {
                        self.run_lane_degraded_single(&mut lanes[l], &plans[l], phase)?;
                        lanes[l].stats.mix.single_residue += 1;
                    }
                    continue;
                }
            };
            let mut at = 0usize;
            for &chunk in &table.splits[batchable.len()] {
                if chunk == 1 {
                    let l = batchable[at];
                    at += 1;
                    if kind == 0 {
                        self.run_lane_single(&mut lanes[l], phase)?;
                    } else {
                        self.run_lane_degraded_single(&mut lanes[l], &plans[l], phase)?;
                    }
                    let lane = &mut lanes[l];
                    if kind == 0 && lane.accel.wants_aux_capture(lane.step) {
                        lane.stats.mix.single_capture += 1;
                    } else {
                        lane.stats.mix.single_residue += 1;
                    }
                    continue;
                }
                let lo = at;
                at += chunk;
                if kind == 0 {
                    self.run_lane_bucket(lanes, &batchable[lo..at], &table.variants, phase)?;
                } else {
                    self.run_degraded_bucket(
                        lanes,
                        &batchable[lo..at],
                        &plans[lead],
                        &table.variants,
                        phase,
                    )?;
                }
            }
        }
        Ok(())
    }

    /// Single-lane Shallow/Prune execution — the same per-lane discipline
    /// [`Pipeline::generate`] uses (deep handoff by move, arena-cycled
    /// caches refresh), so a degraded lane executed alone is bit-identical
    /// to sequential generation.
    fn run_lane_degraded_single(
        &self,
        lane: &mut Lane,
        plan: &StepPlan,
        phase: &mut PhaseAccum,
    ) -> Result<()> {
        let t_norm = lane.solver.t_norm(lane.step);
        match plan {
            StepPlan::Shallow => {
                let mut t0 = phase.mark();
                // xtask: allow(panic): persistent x slot — Some for the whole run
                lane.args.x.as_mut().expect("persistent x slot").copy_from(&lane.x);
                lane.args.t = t_norm as f32;
                // move (not clone) the deep feature into the args and
                // back: the shallow variant reads it but emits none
                lane.args.deep = lane.deep.take();
                let run =
                    self.backend.run_into("shallow", &lane.args, &mut lane.m_out, None, None);
                if let Some(d) = lane.args.deep.take() {
                    lane.deep.install(d);
                }
                run?;
                phase.model_us += PhaseAccum::lap(&mut t0);
                lane.executed = true;
            }
            StepPlan::Prune { mask } => {
                // shared prune discipline (arena-cycled caches refresh):
                // the same single owner Pipeline::generate executes
                let mut t0 = phase.mark();
                self.run_prune_into(
                    &mut lane.args,
                    mask,
                    &lane.x,
                    t_norm,
                    &mut lane.m_out,
                    &mut lane.caches,
                )?;
                phase.model_us += PhaseAccum::lap(&mut t0);
                lane.executed = true;
            }
            _ => anyhow::bail!("degraded single called with a non-degraded plan"),
        }
        Ok(())
    }

    /// Single-lane full execution: the same code path as the Full arm of
    /// [`Pipeline::generate`] (including deep/caches capture), so a lane
    /// executed alone is bit-identical to sequential generation.
    fn run_lane_single(&self, lane: &mut Lane, phase: &mut PhaseAccum) -> Result<()> {
        let t_norm = lane.solver.t_norm(lane.step);
        let mut t0 = phase.mark();
        // xtask: allow(panic): persistent x slot — Some for the whole run
        lane.args.x.as_mut().expect("persistent x slot").copy_from(&lane.x);
        lane.args.t = t_norm as f32;
        self.backend.run_into(
            "full",
            &lane.args,
            &mut lane.m_out,
            Some(lane.deep.slot()),
            Some(lane.caches.slot()),
        )?;
        phase.model_us += PhaseAccum::lap(&mut t0);
        // single full executions refresh the aux features their signature
        // declares (empty signatures follow the run_into contract: full
        // emits both); an unemitted slot keeps its previous validity
        let info = self.backend.info();
        if info.emits_output("full", "deep") {
            lane.deep.mark_valid();
        }
        if info.emits_output("full", "caches") {
            lane.caches.mark_valid();
        }
        lane.executed = true;
        Ok(())
    }

    /// Bucketed full execution of `sub` (>= 2 lanes, one variant
    /// signature): lane states and conds are gathered row-wise into
    /// arena-pooled `[chunk, ...]` buffers, the compiled `full_b{chunk}`
    /// variant runs into a pooled output buffer, and rows scatter back
    /// into each lane's `m_out` in place. Aux outputs the signature emits
    /// come back batch-major — row k is exactly what lane k's solo single
    /// would have captured — and scatter into each lane's retained
    /// [`AuxSlot`]s (the multi-row CacheWarm capture). Every buffer
    /// returns to the arena, so the steady state allocates nothing.
    fn run_lane_bucket(
        &self,
        lanes: &mut [Lane],
        sub: &[usize],
        bucket_variants: &[(usize, String)],
        phase: &mut PhaseAccum,
    ) -> Result<()> {
        let chunk = sub.len();
        let info = self.backend.info();
        let [h, w, c] = info.img;
        // every member shares the lead lane's (t, gs) by group construction
        let t_norm = lanes[sub[0]].solver.t_norm(lanes[sub[0]].step);
        let gs = lanes[sub[0]].req.guidance;
        let variant = bucket_variants
            .iter()
            .find(|(n, _)| *n == chunk)
            .map(|(_, v)| v.as_str());
        let variant = match variant {
            Some(v) => v,
            None => anyhow::bail!("no compiled bucket variant for a {chunk}-lane chunk"),
        };
        let mut t0 = phase.mark();
        let mut xb = self.arena.checkout(&[chunk, h, w, c]);
        let mut cb = self.arena.checkout(&[chunk, info.cond_dim]);
        for (k, &l) in sub.iter().enumerate() {
            view::copy_into_row(&mut xb, k, &lanes[l].x);
            view::copy_into_row(&mut cb, k, &lanes[l].req.cond);
        }
        let mut out_b = self.arena.checkout(&[chunk, h, w, c]);
        let mut args = ModelArgs {
            x: Some(xb),
            t: t_norm as f32,
            cond: Some(cb),
            gs,
            ..Default::default()
        };
        // batch-major aux capture buffers, only for what the bucket's
        // signature (== its batch-1 twin's) emits
        let ds = info.deep_shape();
        let cs = info.caches_shape();
        let mut deep_b = if info.emits_output(variant, "deep") {
            Some(self.arena.checkout(&[chunk, ds[0], ds[1], ds[2]]))
        } else {
            None
        };
        let mut caches_b = if info.emits_output(variant, "caches") {
            Some(self.arena.checkout(&[chunk, cs[0], cs[1], cs[2], cs[3]]))
        } else {
            None
        };
        phase.gather_us += PhaseAccum::lap(&mut t0);
        let want_deep = deep_b.is_some();
        let want_caches = caches_b.is_some();
        let run = self.backend.run_into(
            variant,
            &args,
            &mut out_b,
            if want_deep { Some(&mut deep_b) } else { None },
            if want_caches { Some(&mut caches_b) } else { None },
        );
        phase.model_us += PhaseAccum::lap(&mut t0);
        // gather buffers go back to the pool whatever happened
        self.arena.release_opt(args.x.take());
        self.arena.release_opt(args.cond.take());
        match run {
            Ok(()) => {}
            Err(e) => {
                self.arena.release(out_b);
                self.arena.release_opt(deep_b.take());
                self.arena.release_opt(caches_b.take());
                return Err(e);
            }
        }
        for (k, &l) in sub.iter().enumerate() {
            let lane = &mut lanes[l];
            view::copy_from_row(&mut lane.m_out, &out_b, k);
            lane.executed = true;
            lane.stats.mix.batched += 1;
            // scatter each lane's captured aux rows into its retained
            // slots and mark them fresh — the same refresh its solo
            // single performs, so CacheWarm capture steps batch too
            if let Some(db) = deep_b.as_ref() {
                if let Some(slot) = lane.deep.slot().as_mut() {
                    view::copy_from_row(slot, db, k);
                }
                lane.deep.mark_valid();
            }
            if let Some(cbuf) = caches_b.as_ref() {
                if let Some(slot) = lane.caches.slot().as_mut() {
                    view::copy_from_row(slot, cbuf, k);
                }
                lane.caches.mark_valid();
            }
        }
        self.arena.release(out_b);
        self.arena.release_opt(deep_b.take());
        self.arena.release_opt(caches_b.take());
        phase.scatter_us += PhaseAccum::lap(&mut t0);
        Ok(())
    }

    /// Bucketed degraded execution of `sub` (>= 2 lanes, one variant
    /// signature): like [`Pipeline::run_lane_bucket`], plus the per-lane
    /// aux features the variant *consumes* are gathered row-wise into
    /// arena-pooled batch-major buffers — Shallow reads each lane's deep
    /// feature, Prune reads each lane's attention caches and, when the
    /// signature emits `caches`, refreshes them through a pooled buffer
    /// scattered back per row (the batched twin of
    /// [`Pipeline::run_prune_into`]'s install). Every buffer returns to
    /// the arena, so the steady state allocates nothing.
    fn run_degraded_bucket(
        &self,
        lanes: &mut [Lane],
        sub: &[usize],
        plan: &StepPlan,
        bucket_variants: &[(usize, String)],
        phase: &mut PhaseAccum,
    ) -> Result<()> {
        let chunk = sub.len();
        let info = self.backend.info();
        let [h, w, c] = info.img;
        // every member shares the lead lane's (t, gs, mask) by group
        // construction + the mask-equality guard
        let t_norm = lanes[sub[0]].solver.t_norm(lanes[sub[0]].step);
        let gs = lanes[sub[0]].req.guidance;
        let variant = bucket_variants
            .iter()
            .find(|(n, _)| *n == chunk)
            .map(|(_, v)| v.as_str());
        let variant = match variant {
            Some(v) => v,
            None => anyhow::bail!("no compiled bucket variant for a {chunk}-lane chunk"),
        };
        let mut t0 = phase.mark();
        let mut xb = self.arena.checkout(&[chunk, h, w, c]);
        let mut cb = self.arena.checkout(&[chunk, info.cond_dim]);
        for (k, &l) in sub.iter().enumerate() {
            view::copy_into_row(&mut xb, k, &lanes[l].x);
            view::copy_into_row(&mut cb, k, &lanes[l].req.cond);
        }
        let mut args = ModelArgs {
            x: Some(xb),
            t: t_norm as f32,
            cond: Some(cb),
            gs,
            ..Default::default()
        };
        // gather the aux inputs the variant consumes, batch-major: the
        // structural fallback guarantees every gathered lane's slot holds
        // a valid feature
        let mut refresh_caches = false;
        match plan {
            StepPlan::Shallow => {
                let ds = info.deep_shape();
                let mut db = self.arena.checkout(&[chunk, ds[0], ds[1], ds[2]]);
                for (k, &l) in sub.iter().enumerate() {
                    match lanes[l].deep.slot().as_ref() {
                        Some(d) => view::copy_into_row(&mut db, k, d),
                        None => anyhow::bail!("batched Shallow lane lost its deep slot"),
                    }
                }
                args.deep = Some(db);
            }
            StepPlan::Prune { mask } => {
                let cs = info.caches_shape();
                let mut kb = self.arena.checkout(&[chunk, cs[0], cs[1], cs[2], cs[3]]);
                for (k, &l) in sub.iter().enumerate() {
                    match lanes[l].caches.slot().as_ref() {
                        Some(cc) => view::copy_into_row(&mut kb, k, cc),
                        None => anyhow::bail!("batched Prune lane lost its caches slot"),
                    }
                }
                args.caches = Some(kb);
                // xtask: allow(alloc): Arc refcount bump, no heap allocation
                args.keep_idx = Some(mask.clone());
                refresh_caches = info.emits_output(variant, "caches");
            }
            _ => anyhow::bail!("degraded bucket called with a non-degraded plan"),
        }
        let mut out_b = self.arena.checkout(&[chunk, h, w, c]);
        let cs = info.caches_shape();
        let mut refreshed = if refresh_caches {
            Some(self.arena.checkout(&[chunk, cs[0], cs[1], cs[2], cs[3]]))
        } else {
            None
        };
        phase.gather_us += PhaseAccum::lap(&mut t0);
        let run = self.backend.run_into(
            variant,
            &args,
            &mut out_b,
            None,
            if refresh_caches { Some(&mut refreshed) } else { None },
        );
        phase.model_us += PhaseAccum::lap(&mut t0);
        // gather buffers go back to the pool whatever happened
        self.arena.release_opt(args.x.take());
        self.arena.release_opt(args.cond.take());
        self.arena.release_opt(args.deep.take());
        self.arena.release_opt(args.caches.take());
        args.keep_idx = None;
        match run {
            Ok(()) => {}
            Err(e) => {
                self.arena.release(out_b);
                self.arena.release_opt(refreshed.take());
                return Err(e);
            }
        }
        for (k, &l) in sub.iter().enumerate() {
            let lane = &mut lanes[l];
            view::copy_from_row(&mut lane.m_out, &out_b, k);
            lane.executed = true;
            lane.stats.mix.batched += 1;
            // scatter each lane's refreshed caches row into its retained
            // slot (still valid — the gathered input was)
            if let Some(rb) = refreshed.as_ref() {
                if let Some(cc) = lane.caches.slot().as_mut() {
                    view::copy_from_row(cc, rb, k);
                }
            }
        }
        self.arena.release(out_b);
        self.arena.release_opt(refreshed.take());
        phase.scatter_us += PhaseAccum::lap(&mut t0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::NoAccel;
    use crate::runtime::mock::GmBackend;
    use crate::runtime::ModelBackend;
    use crate::sada::Sada;
    use crate::solvers::SolverKind;
    use crate::testutil::{check, UsizeIn};

    fn reqs_for(n: usize, steps: usize, seed: u64) -> Vec<GenRequest> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n)
            .map(|k| GenRequest {
                cond: Tensor::from_rng(&mut rng, &[1, 32]),
                seed: rng.below(10_000),
                guidance: [0.0f32, 2.0, 5.0][k % 3],
                steps,
                edge: None,
            })
            .collect()
    }

    /// Queue feeder for continuous-engine tests: admits at most
    /// `max_per_event` queued lanes per offer, collects `(tag, result)`
    /// pairs in completion order.
    struct QueueFeeder {
        queue: Vec<AdmittedLane>,
        max_per_event: usize,
        results: Vec<(u64, GenResult)>,
    }

    impl QueueFeeder {
        fn new(queue: Vec<AdmittedLane>, max_per_event: usize) -> Self {
            Self { queue, max_per_event, results: Vec::new() }
        }
    }

    impl LaneFeeder for QueueFeeder {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            let n = free.min(self.max_per_event).min(self.queue.len());
            self.queue.drain(..n).collect()
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            self.results.push((tag, result));
        }
    }

    fn admitted_for(
        reqs: &[GenRequest],
        make: impl Fn(usize) -> Box<dyn Accelerator>,
    ) -> Vec<AdmittedLane> {
        reqs.iter()
            .enumerate()
            .map(|(k, r)| AdmittedLane { req: r.clone(), accel: make(k), tag: k as u64 })
            .collect()
    }

    #[test]
    fn property_noaccel_lanes_bit_identical_to_sequential() {
        // any seed/batch mix, with and without compiled batch buckets
        check(5, 10, &UsizeIn(1, 6), |b| {
            for bucketed in [false, true] {
                let backend = if bucketed {
                    GmBackend::with_batch_buckets(3, &[2, 4])
                } else {
                    GmBackend::new(3)
                };
                let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
                let reqs = reqs_for(*b, 8, *b as u64 * 31 + 7);
                let proto: &dyn Accelerator = &NoAccel;
                let lanes = pipe
                    .generate_lanes(&reqs, proto)
                    .map_err(|e| format!("lane engine failed: {e:#}"))?;
                for (k, (lane, req)) in lanes.iter().zip(&reqs).enumerate() {
                    let solo = pipe
                        .generate(req, &mut NoAccel)
                        .map_err(|e| format!("sequential failed: {e:#}"))?;
                    if lane.image.data() != solo.image.data() {
                        return Err(format!(
                            "lane {k} (bucketed={bucketed}, b={b}) not bit-identical"
                        ));
                    }
                    if lane.stats.nfe != solo.stats.nfe {
                        return Err(format!("lane {k} nfe {} != {}", lane.stats.nfe, solo.stats.nfe));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_exact_bucket_required_and_buckets_shrink_model_calls() {
        // 5 lanes with only full_b2 compiled: chunks [2, 2, 1] per step
        let backend = GmBackend::with_batch_buckets(4, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let steps = 6;
        let reqs: Vec<GenRequest> = reqs_for(5, steps, 11)
            .into_iter()
            .map(|mut r| {
                r.guidance = 3.0; // one guidance group: maximal gathering
                r
            })
            .collect();
        backend.reset_nfe();
        let proto: &dyn Accelerator = &NoAccel;
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        assert_eq!(out.len(), 5);
        // 3 launches per step instead of 5 sequential calls
        assert_eq!(backend.nfe(), steps * 3);
        for lane in &out {
            assert_eq!(lane.stats.nfe, steps);
        }
    }

    #[test]
    fn duplicate_lanes_are_deterministic_and_divergent_lanes_decide_independently() {
        // two identical lanes must produce identical traces; across GM
        // landscapes, a smooth (gs=0) and a strongly-guided (gs=8) lane
        // must make different SADA skip decisions in the same batch
        let steps = 50;
        let mut any_diverged = false;
        for seed in 0..12u64 {
            let backend = GmBackend::with_batch_buckets(seed + 1, &[2]);
            let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
            let mut rng = crate::rng::Rng::new(900 + seed);
            let smooth = GenRequest {
                cond: Tensor::zeros(&[1, 32]),
                seed: 7,
                guidance: 0.0,
                steps,
                edge: None,
            };
            let jagged = GenRequest {
                cond: Tensor::from_rng(&mut rng, &[1, 32]),
                seed: 8 + seed,
                guidance: 8.0,
                steps,
                edge: None,
            };
            let proto = Sada::with_default(backend.info(), steps);
            let proto: &dyn Accelerator = &proto;
            let twin = pipe
                .generate_lanes(&[smooth.clone(), smooth.clone()], proto)
                .unwrap();
            assert_eq!(
                twin[0].stats.mode_trace(),
                twin[1].stats.mode_trace(),
                "identical lanes must decide identically"
            );
            assert_eq!(twin[0].image.data(), twin[1].image.data());
            let pair = pipe.generate_lanes(&[smooth, jagged], proto).unwrap();
            if pair[0].stats.mode_trace() != pair[1].stats.mode_trace() {
                any_diverged = true;
                break;
            }
        }
        assert!(
            any_diverged,
            "divergent trajectories never produced different per-lane skip decisions (12 seeds)"
        );
    }

    #[test]
    fn per_lane_beats_lockstep_on_some_divergent_workload() {
        // the serving claim in miniature: independent lanes skip more than
        // a conservative global decision on at least one divergent batch
        let steps = 50;
        let mut found = false;
        for seed in 0..12u64 {
            let backend = GmBackend::with_batch_buckets(seed + 2, &[2, 4]);
            let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
            let reqs = reqs_for(4, steps, 70 + seed);
            let proto = Sada::with_default(backend.info(), steps);
            let proto: &dyn Accelerator = &proto;
            let per_lane = pipe.generate_lanes(&reqs, proto).unwrap();
            let lockstep = pipe
                .generate_lanes_mode(&reqs, proto, LaneMode::Lockstep)
                .unwrap();
            let nfe = |rs: &[GenResult]| rs.iter().map(|r| r.stats.nfe).sum::<usize>();
            if nfe(&per_lane) < nfe(&lockstep) {
                found = true;
                break;
            }
        }
        assert!(found, "per-lane NFE never beat lockstep across 12 workloads");
    }

    #[test]
    fn lane_batch_of_one_matches_generate() {
        let backend = GmBackend::new(6);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let reqs = reqs_for(1, 9, 5);
        let proto: &dyn Accelerator = &NoAccel;
        let lane = pipe.generate_lanes(&reqs, proto).unwrap();
        let solo = pipe.generate(&reqs[0], &mut NoAccel).unwrap();
        assert_eq!(lane[0].image.data(), solo.image.data());
        assert_eq!(lane[0].stats.mode_trace(), solo.stats.mode_trace());
    }

    #[test]
    fn lane_engine_rejects_bad_batches() {
        let backend = GmBackend::new(6);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let proto: &dyn Accelerator = &NoAccel;
        assert!(pipe.generate_lanes(&[], proto).is_err());
        let mut reqs = reqs_for(2, 5, 1);
        reqs[1].steps = 9;
        assert!(pipe.generate_lanes(&reqs, proto).is_err());
    }

    #[test]
    fn mixed_guidance_lanes_execute_in_separate_sub_batches() {
        // two guidance groups over full_b2: every lane still matches its
        // own sequential run exactly
        let backend = GmBackend::with_batch_buckets(8, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let mut reqs = reqs_for(4, 7, 21);
        reqs[0].guidance = 1.0;
        reqs[1].guidance = 4.0;
        reqs[2].guidance = 1.0;
        reqs[3].guidance = 4.0;
        let proto: &dyn Accelerator = &NoAccel;
        let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
        for (lane, req) in lanes.iter().zip(&reqs) {
            let solo = pipe.generate(req, &mut NoAccel).unwrap();
            assert_eq!(lane.image.data(), solo.image.data());
        }
    }

    #[test]
    fn deepcache_lanes_keep_shallow_acceleration_without_buckets() {
        // no compiled buckets: every full run is a single, so lanes track
        // deep features lane-locally and the shallow path survives
        // batching — bit-identical to per-request sequential generation
        let backend = GmBackend::new(11);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let reqs = reqs_for(2, 12, 44);
        let proto = crate::baselines::DeepCache::new(3);
        let proto: &dyn Accelerator = &proto;
        let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
        for (lane, req) in lanes.iter().zip(&reqs) {
            assert!(
                lane.stats.count(crate::pipeline::StepMode::Shallow) > 4,
                "shallow discount lost under batching: trace={}",
                lane.stats.mode_trace()
            );
            let solo = pipe
                .generate(req, &mut crate::baselines::DeepCache::new(3))
                .unwrap();
            assert_eq!(lane.image.data(), solo.image.data());
            assert_eq!(lane.stats.mode_trace(), solo.stats.mode_trace());
        }
    }

    #[test]
    fn fn_factory_builds_heterogeneous_lanes() {
        let backend = GmBackend::new(9);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let steps = 30;
        let reqs = reqs_for(2, steps, 33);
        let info = backend.info().clone();
        let factory = FnFactory(move |lane: usize| -> Box<dyn Accelerator> {
            if lane == 0 {
                Box::new(NoAccel)
            } else {
                Box::new(Sada::with_default(&info, steps))
            }
        });
        let lanes = pipe.generate_lanes(&reqs, &factory).unwrap();
        assert_eq!(lanes[0].stats.accel, "baseline");
        assert_eq!(lanes[1].stats.accel, "sada");
        assert_eq!(lanes[0].stats.nfe, steps);
    }

    #[test]
    fn continuous_staggered_admission_is_bit_identical_to_solo_runs() {
        // trickle admission (one lane per offer) into 2 slots, mixed step
        // counts: every result must match its solo run bitwise, proving
        // admission timing and slot reuse cannot perturb a lane
        let backend = GmBackend::with_batch_buckets(5, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let mut reqs = reqs_for(5, 8, 63);
        for (k, r) in reqs.iter_mut().enumerate() {
            r.steps = [8, 11, 8, 14, 8][k];
        }
        let mut feeder =
            QueueFeeder::new(admitted_for(&reqs, |_| Box::new(NoAccel)), 1);
        let stats = pipe.generate_continuous(2, &mut feeder).unwrap();
        assert_eq!(stats.admitted, 5);
        assert_eq!(stats.completed, 5);
        assert_eq!(feeder.results.len(), 5);
        assert!(stats.occupancy() > 0.5, "stats: {stats:?}");
        assert_eq!(stats.slot_steps, stats.steps * 2);
        for (tag, res) in &feeder.results {
            let solo = pipe.generate(&reqs[*tag as usize], &mut NoAccel).unwrap();
            assert_eq!(
                res.image.data(),
                solo.image.data(),
                "lane tag {tag} not bit-identical to its solo run"
            );
            assert_eq!(res.stats.nfe, solo.stats.nfe);
        }
    }

    #[test]
    fn continuous_slot_reuse_preserves_aux_dependent_accelerators() {
        // unbucketed backend + DeepCache: shallow steps depend on the aux
        // slots admission must invalidate-and-retain. Three waves through
        // one slot: each occupant must match its solo run exactly.
        let backend = GmBackend::new(17);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let reqs = reqs_for(3, 12, 29);
        let mut feeder = QueueFeeder::new(
            admitted_for(&reqs, |_| Box::new(crate::baselines::DeepCache::new(3))),
            1,
        );
        let stats = pipe.generate_continuous(1, &mut feeder).unwrap();
        assert_eq!(stats.completed, 3);
        // one slot, always busy once the queue is non-empty
        assert_eq!(stats.lane_steps, 12 * 3);
        for (tag, res) in &feeder.results {
            let solo = pipe
                .generate(&reqs[*tag as usize], &mut crate::baselines::DeepCache::new(3))
                .unwrap();
            assert_eq!(res.image.data(), solo.image.data(), "occupant {tag}");
            assert_eq!(res.stats.mode_trace(), solo.stats.mode_trace(), "occupant {tag}");
            assert!(res.stats.count(crate::pipeline::StepMode::Shallow) > 4);
        }
    }

    #[test]
    fn continuous_keeps_slots_full_while_queue_is_nonempty() {
        // saturated queue, uniform steps: after the fill ramp the engine
        // must never idle a slot — occupancy equals the ideal packing
        let backend = GmBackend::with_batch_buckets(4, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let mut reqs = reqs_for(6, 10, 47);
        for r in reqs.iter_mut() {
            r.guidance = 3.0;
        }
        let mut feeder = QueueFeeder::new(admitted_for(&reqs, |_| Box::new(NoAccel)), 2);
        let stats = pipe.generate_continuous(2, &mut feeder).unwrap();
        // 6 lanes x 10 steps over 2 always-full slots: exactly 30 steps
        assert_eq!(stats.steps, 30, "stats: {stats:?}");
        assert_eq!(stats.lane_steps, 60);
        assert!((stats.occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn continuous_engine_rejects_feeder_overfill_and_zero_capacity() {
        let backend = GmBackend::new(6);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        struct Greedy(Vec<AdmittedLane>);
        impl LaneFeeder for Greedy {
            fn admit(&mut self, _free: usize) -> Vec<AdmittedLane> {
                std::mem::take(&mut self.0)
            }
            fn complete(&mut self, _tag: u64, _result: GenResult) {}
        }
        let reqs = reqs_for(3, 5, 9);
        let mut greedy = Greedy(admitted_for(&reqs, |_| Box::new(NoAccel)));
        assert!(pipe.generate_continuous(2, &mut greedy).is_err());
        let mut empty = QueueFeeder::new(Vec::new(), 1);
        assert!(pipe.generate_continuous(0, &mut empty).is_err());
        // an empty feeder is a clean no-op run
        let stats = pipe.generate_continuous(2, &mut empty).unwrap();
        assert_eq!(stats.steps, 0);
        assert_eq!(stats.completed, 0);
    }
}
