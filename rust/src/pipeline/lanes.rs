//! Per-lane batched sampling engine.
//!
//! SADA's stability criterion is *per-trajectory* (Criterion 3.4): different
//! prompts stabilize at different times, so a batched sampler that computes
//! one criterion over the concatenated batch forces a single global
//! skip/keep decision on every request — the failure mode AdaDiff attributes
//! to fixed per-prompt budgets. This module replaces that lockstep loop with
//! a **lane engine**: each request in a batch owns a *lane* with its own
//! accelerator instance (via [`Accelerator::clone_fresh`]), its own solver
//! multistep history, and its own [`RunStats`]. Every step:
//!
//! 1. each lane plans independently;
//! 2. lanes planning [`StepPlan::Full`] are gathered row-wise
//!    ([`crate::tensor::view::copy_into_row`]) into arena-pooled bucket
//!    buffers and executed through the largest fitting compiled
//!    `full_b{n}` bucket
//!    ([`crate::runtime::manifest::split_into_buckets`]), grouped by
//!    guidance scalar (a compiled variant takes one `gs` input); oversized
//!    gathers split across several bucket launches plus `full` singles, so
//!    **no compiled bucket of the exact batch size is ever required**;
//! 3. model outputs are scattered back and every lane advances through its
//!    own solver; skipping lanes extrapolate lane-locally (AM-3 /
//!    Lagrange, Thm 3.5–3.7) at zero model cost — a skipping lane drops
//!    out of the model call entirely, shrinking the executed batch.
//!
//! Degraded variants (Shallow/Prune) are compiled at batch 1 only, so
//! lanes planning them execute as per-lane singles with lane-local
//! deep/cache features — batching keeps their per-step discount instead of
//! forcing Full. Aux features are captured only from *single* full
//! executions (bucketed `full_b{n}` launches invalidate them: the batched
//! artifacts' aux layouts are not per-lane sliceable), so on a backend
//! with no compiled buckets the lane engine is feature-equivalent — and
//! bit-identical — to per-request sequential generation, while bucketed
//! lanes trade the degraded-variant discount for gather throughput.
//!
//! **CacheWarm lanes.** A lane replaying a verified cached plan with
//! token-pruned (or shallow) directives signals the fresh step feeding
//! those directives via [`Accelerator::wants_aux_capture`]; the engine
//! runs that execution as a *single* so the attention caches land in the
//! lane's retained [`crate::tensor::arena::AuxSlot`]s, after which Prune
//! directives replay natively — no `caches`-missing degradation — with
//! each pruned step refreshing its own caches through an arena-pooled
//! buffer. Every other full step of the replay still gathers into
//! buckets, so warm replays keep both the NFE cut *and* the co-scheduled
//! bucket throughput.
//!
//! With [`super::NoAccel`] the engine is bit-identical to sequential
//! [`Pipeline::generate`] per request (property-tested below): single-lane
//! chunks share the exact code path, and bucketed chunks are pure
//! gather/compute/scatter.
//!
//! **Memory discipline.** The step loop is zero-allocation at steady
//! state (pinned by `tests/zero_alloc.rs`): every lane owns reusable step
//! buffers (state, model output, data prediction, gradient) written
//! through the solvers' `_into` kernels and [`ModelBackend::run_into`];
//! bucket gathers write lane rows directly into buffers checked out from
//! the pipeline's [`crate::tensor::arena::TensorArena`] (released after
//! the scatter); and the per-step bookkeeping (plans, guidance groups,
//! bucket splits) lives in vectors allocated once before the loop.

use anyhow::Result;

use super::{
    apply_structural_fallbacks, Accelerator, GenRequest, GenResult, Pipeline, RunStats, StepCtx,
    StepObs, StepPlan,
};
use crate::runtime::manifest::split_into_buckets;
use crate::runtime::{ModelArgs, ModelBackend, ModelInfo};
use crate::solvers::{build_solver, Solver};
use crate::tensor::arena::AuxSlot;
use crate::tensor::{view, Tensor};

/// Makers of fresh per-lane accelerator instances.
pub trait AcceleratorFactory {
    /// Build the accelerator for lane index `lane`.
    fn make(&self, lane: usize) -> Box<dyn Accelerator>;
}

/// Any accelerator prototype is the factory for its own lane copies.
impl AcceleratorFactory for dyn Accelerator {
    fn make(&self, _lane: usize) -> Box<dyn Accelerator> {
        self.clone_fresh()
    }
}

/// Adapter: build per-lane accelerators from a closure (heterogeneous
/// lane configurations, test harnesses).
pub struct FnFactory<F>(pub F);

impl<F: Fn(usize) -> Box<dyn Accelerator>> AcceleratorFactory for FnFactory<F> {
    fn make(&self, lane: usize) -> Box<dyn Accelerator> {
        (self.0)(lane)
    }
}

/// Execution discipline of the lane engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneMode {
    /// Every lane plans and executes independently (the SADA-faithful
    /// default).
    PerLane,
    /// Global-decision arm for per-lane-vs-lockstep sweeps: whenever any
    /// lane needs a fresh execution, every lane executes. This models the
    /// *regime* the retired lockstep batch path imposed — one skip/keep
    /// decision for the whole batch — not its exact implementation (which
    /// evaluated a single criterion over the concatenated tensor and
    /// required a compiled bucket of the exact batch size).
    Lockstep,
}

/// One request's private slice of the batch, with its reusable step
/// buffers (the zero-allocation discipline: buffers are written in place
/// every step and swapped, never reallocated).
struct Lane<'r> {
    req: &'r GenRequest,
    solver: Box<dyn Solver>,
    accel: Box<dyn Accelerator>,
    wants_obs: bool,
    /// Current state x_i (swapped with `x_next` after every step).
    x: Tensor,
    x_next: Tensor,
    /// This step's model output (swapped with `last_out` after the step).
    m_out: Tensor,
    last_out: Tensor,
    has_last: bool,
    /// Whether `m_out` holds a fresh execution for the current step.
    executed: bool,
    x0: Tensor,
    y: Tensor,
    /// Persistent model args: `x` slot copied in place per call, cond/edge
    /// cloned once at lane init.
    args: ModelArgs,
    /// DeepCache deep feature from this lane's last *single* full run.
    /// Bucketed launches *invalidate* it (batched aux layouts are not
    /// per-lane sliceable) but retain the buffer — sourced from the
    /// pipeline arena — for in-place refill by the next single.
    deep: AuxSlot,
    /// Attention caches from this lane's last single full/prune run
    /// (same retained-slot discipline).
    caches: AuxSlot,
    stats: RunStats,
}

/// Step-loop bookkeeping allocated once per `generate_lanes` call and
/// reused every step (cleared, never reallocated at steady state).
struct LaneScratch {
    /// Per-step plans, lane-indexed.
    plans: Vec<StepPlan>,
    /// Guidance groups: parallel key/member vectors in first-appearance
    /// order; member vectors are recycled across steps.
    group_keys: Vec<u32>,
    group_members: Vec<Vec<usize>>,
    /// Per-group partition of members into edge-conditioned singles and
    /// batchable lanes.
    singles: Vec<usize>,
    batchable: Vec<usize>,
    /// `splits[n]` = fewest-launches chunk plan for an n-lane gather
    /// (precomputed for every possible gather size).
    splits: Vec<Vec<usize>>,
    /// Compiled `full_b{n}` variant names, built once.
    bucket_variants: Vec<(usize, String)>,
}

impl<'a, B: ModelBackend> Pipeline<'a, B> {
    /// Run a batch of requests through the per-lane engine. Requests must
    /// share a step count; seeds, conds, guidance and edges may differ
    /// (mixed-guidance lanes execute in separate sub-batches).
    pub fn generate_lanes<F: AcceleratorFactory + ?Sized>(
        &self,
        reqs: &[GenRequest],
        factory: &F,
    ) -> Result<Vec<GenResult>> {
        self.generate_lanes_mode(reqs, factory, LaneMode::PerLane)
    }

    /// [`Pipeline::generate_lanes`] with an explicit [`LaneMode`].
    pub fn generate_lanes_mode<F: AcceleratorFactory + ?Sized>(
        &self,
        reqs: &[GenRequest],
        factory: &F,
        mode: LaneMode,
    ) -> Result<Vec<GenResult>> {
        anyhow::ensure!(!reqs.is_empty(), "empty batch");
        let steps = reqs[0].steps;
        anyhow::ensure!(
            reqs.iter().all(|r| r.steps == steps),
            "lane batch must share step count"
        );
        // xtask: allow(alloc, begin): per-batch init — lane state, step
        // buffers, bucket-split tables and aux slots are allocated once
        // here; the per-step loop below reuses them in place
        let info = self.backend.info().clone();
        let buckets = info.full_batch_buckets();
        let [h, w, c] = info.img;
        let shape = [1, h, w, c];

        let mut lanes: Vec<Lane> = reqs
            .iter()
            .enumerate()
            .map(|(li, req)| {
                let mut solver = build_solver(self.solver_kind, self.schedule(), steps);
                solver.reset();
                let mut accel = factory.make(li);
                accel.reset();
                accel.begin_run(req);
                let mut rng = crate::rng::Rng::new(req.seed);
                let x = Tensor::from_rng(&mut rng, &shape);
                let stats = RunStats::new(accel.name(), steps);
                let wants_obs = accel.wants_obs();
                // aux slots hold arena buffers for the whole run (retired
                // at the end), so single captures refill in place
                let mut deep = AuxSlot::new();
                let mut caches = AuxSlot::new();
                deep.ensure(&self.arena, &info.deep_shape());
                caches.ensure(&self.arena, &info.caches_shape());
                Lane {
                    req,
                    solver,
                    wants_obs,
                    accel,
                    x,
                    x_next: Tensor::zeros(&shape),
                    m_out: Tensor::zeros(&shape),
                    last_out: Tensor::zeros(&shape),
                    has_last: false,
                    executed: false,
                    x0: Tensor::zeros(&shape),
                    y: Tensor::zeros(&shape),
                    args: ModelArgs {
                        x: Some(Tensor::zeros(&shape)),
                        t: 0.0,
                        cond: Some(req.cond.clone()),
                        gs: req.guidance,
                        edge: req.edge.clone(),
                        ..Default::default()
                    },
                    deep,
                    caches,
                    stats,
                }
            })
            .collect();

        // step-loop bookkeeping, allocated once (steady-state steps reuse)
        let mut sc = LaneScratch {
            plans: Vec::with_capacity(lanes.len()),
            group_keys: Vec::with_capacity(lanes.len()),
            group_members: Vec::new(),
            singles: Vec::with_capacity(lanes.len()),
            batchable: Vec::with_capacity(lanes.len()),
            splits: (0..=lanes.len()).map(|n| split_into_buckets(n, &buckets)).collect(),
            bucket_variants: buckets
                .iter()
                .map(|&n| (n, ModelInfo::full_variant_for(n)))
                .collect(),
        };
        // xtask: allow(alloc, end)

        let timer = crate::report::Timer::start();
        for i in 0..steps {
            // 1) every lane plans independently from its own history
            sc.plans.clear();
            for lane in lanes.iter_mut() {
                let ctx = StepCtx {
                    i,
                    n_steps: steps,
                    x: &lane.x,
                    t_norm: lane.solver.t_norm(i),
                    have_caches: lane.caches.is_valid(),
                    have_deep: lane.deep.is_valid(),
                };
                let planned = lane.accel.plan(&ctx);
                // structural fallbacks: the shared rule owns the warm/cold
                // decision (same contract as Pipeline::generate)
                let (plan, degraded) = apply_structural_fallbacks(
                    planned,
                    lane.deep.is_valid(),
                    lane.caches.is_valid(),
                    lane.has_last,
                );
                if let Some(mode) = degraded {
                    lane.stats.record_degraded(mode);
                }
                sc.plans.push(plan);
            }
            if mode == LaneMode::Lockstep
                && sc.plans.iter().any(|p| {
                    !matches!(
                        p,
                        StepPlan::SkipReuse | StepPlan::SkipExtrapolate | StepPlan::SkipLagrange
                    )
                })
            {
                for p in sc.plans.iter_mut() {
                    *p = StepPlan::Full;
                }
            }

            // 2) execute: degraded variants as per-lane singles, Full lanes
            //    gathered bucket-aware into arena buffers
            for lane in lanes.iter_mut() {
                lane.executed = false;
            }
            self.execute_planned_lanes(&mut lanes, i, &mut sc)?;

            // 3) every lane advances through its own solver + accelerator.
            // The arms below mirror Pipeline::generate's step body — keep
            // the two in lockstep (the NoAccel/DeepCache bit-identity
            // property tests pin the executed paths against drift).
            for (l, lane) in lanes.iter_mut().enumerate() {
                let plan = &sc.plans[l];
                let t_norm = lane.solver.t_norm(i);
                let fresh = lane.executed;
                match plan {
                    StepPlan::Full | StepPlan::Shallow | StepPlan::Prune { .. } => {
                        anyhow::ensure!(lane.executed, "executed lane lost its output");
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                    StepPlan::SkipReuse => {
                        anyhow::ensure!(lane.has_last, "SkipReuse without history");
                        lane.m_out.copy_from(&lane.last_out);
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                    StepPlan::SkipExtrapolate => {
                        anyhow::ensure!(lane.has_last, "SkipExtrapolate without history");
                        lane.m_out.copy_from(&lane.last_out);
                        lane.solver.x0_from_model_into(&lane.x, &lane.m_out, i, &mut lane.x0);
                        lane.solver.gradient_into(&lane.x, &lane.m_out, i, &mut lane.y);
                        let dt = lane.solver.dt(i);
                        if !lane.accel.extrapolate_into(&lane.x, &lane.y, dt, &mut lane.x_next) {
                            crate::tensor::ops::lincomb2_into(
                                1.0,
                                &lane.x,
                                -(dt as f32),
                                &lane.y,
                                &mut lane.x_next,
                            );
                        }
                        lane.solver.inject_x0(&lane.x0, i);
                    }
                    StepPlan::SkipLagrange => {
                        anyhow::ensure!(
                            lane.accel.reconstruct_x0_into(t_norm, &mut lane.x0),
                            "SkipLagrange without a filled x0 buffer"
                        );
                        lane.solver.model_out_from_x0_into(&lane.x, &lane.x0, i, &mut lane.m_out);
                        lane.solver.step_into(&lane.x, &lane.x0, i, &mut lane.x_next);
                    }
                }
                if lane.wants_obs {
                    // the SkipExtrapolate arm already computed this
                    // gradient from the same inputs
                    if !matches!(plan, StepPlan::SkipExtrapolate) {
                        lane.solver.gradient_into(&lane.x, &lane.m_out, i, &mut lane.y);
                    }
                    let obs = StepObs {
                        i,
                        n_steps: steps,
                        fresh,
                        x_prev: &lane.x,
                        x_next: &lane.x_next,
                        model_out: &lane.m_out,
                        x0: &lane.x0,
                        y: &lane.y,
                        dt: lane.solver.dt(i),
                        t_norm,
                    };
                    lane.accel.observe(&obs);
                }
                lane.stats.record_step(plan, fresh);
                std::mem::swap(&mut lane.m_out, &mut lane.last_out);
                lane.has_last = true;
                std::mem::swap(&mut lane.x, &mut lane.x_next);
            }
        }

        let wall_ms = timer.elapsed_ms();
        // aux buffers go back to the pool for the next batch's lanes
        for lane in lanes.iter_mut() {
            lane.deep.retire(&self.arena);
            lane.caches.retire(&self.arena);
        }
        // xtask: allow(alloc, begin): end-of-run results assembly, not steady state
        Ok(lanes
            .into_iter()
            .map(|mut lane| {
                lane.stats.wall_ms = wall_ms;
                lane.stats.nfe = lane.stats.fresh_steps;
                lane.stats.outcome = lane.accel.outcome();
                lane.stats.degraded.add(&lane.accel.planned_degradations());
                GenResult { image: lane.x, stats: lane.stats }
            })
            .collect())
        // xtask: allow(alloc, end)
    }

    /// Execute every lane whose plan needs the model at step `i`, writing
    /// outputs into each lane's `m_out` buffer (`executed` marks success).
    /// Shallow/Prune lanes run as singles with lane-local aux features
    /// (those variants are compiled at batch 1 only). Full lanes are
    /// grouped by guidance scalar (one `gs` input per compiled variant),
    /// edge-conditioned lanes run as singles (edge inputs are only
    /// compiled for batch-1 variants), and each group is chunked across
    /// the compiled `full_b{n}` buckets through arena-pooled gather
    /// buffers.
    fn execute_planned_lanes(&self, lanes: &mut [Lane], i: usize, sc: &mut LaneScratch) -> Result<()> {
        // degraded variants: per-lane singles, mirroring Pipeline::generate
        for (l, plan) in sc.plans.iter().enumerate() {
            match plan {
                StepPlan::Shallow => {
                    let lane = &mut lanes[l];
                    let t_norm = lane.solver.t_norm(i);
                    // xtask: allow(panic): persistent x slot — Some for the whole run
                    lane.args.x.as_mut().expect("persistent x slot").copy_from(&lane.x);
                    lane.args.t = t_norm as f32;
                    // move (not clone) the deep feature into the args and
                    // back: the shallow variant reads it but emits none
                    lane.args.deep = lane.deep.take();
                    let run = self.backend.run_into("shallow", &lane.args, &mut lane.m_out, None, None);
                    if let Some(d) = lane.args.deep.take() {
                        lane.deep.install(d);
                    }
                    run?;
                    lane.executed = true;
                }
                StepPlan::Prune { mask } => {
                    // shared prune discipline (arena-cycled caches refresh):
                    // the same single owner Pipeline::generate executes
                    let lane = &mut lanes[l];
                    let t_norm = lane.solver.t_norm(i);
                    self.run_prune_into(
                        &mut lane.args,
                        mask,
                        &lane.x,
                        t_norm,
                        &mut lane.m_out,
                        &mut lane.caches,
                    )?;
                    lane.executed = true;
                }
                _ => {}
            }
        }
        // Full lanes: group by guidance bits, preserving lane order
        // (reused key/member vectors — no per-step allocation once every
        // distinct guidance value has appeared)
        sc.group_keys.clear();
        for members in sc.group_members.iter_mut() {
            members.clear();
        }
        for (l, plan) in sc.plans.iter().enumerate() {
            if *plan != StepPlan::Full {
                continue;
            }
            let key = lanes[l].req.guidance.to_bits();
            let gi = match sc.group_keys.iter().position(|k| *k == key) {
                Some(gi) => gi,
                None => {
                    sc.group_keys.push(key);
                    if sc.group_members.len() < sc.group_keys.len() {
                        // xtask: allow(alloc): grows only when a new distinct
                        // guidance value first appears, then is reused
                        sc.group_members.push(Vec::new());
                    }
                    sc.group_keys.len() - 1
                }
            };
            sc.group_members[gi].push(l);
        }
        for gi in 0..sc.group_keys.len() {
            // co-schedule lanes replaying the same verified cached plan
            // into the same bucket chunk: their fresh steps coincide for
            // the rest of the run, so keeping them adjacent maximizes
            // full-bucket gathers on later steps. Stable sort: unkeyed
            // lanes keep lane order (slices this short sort in place).
            sc.group_members[gi].sort_by_key(|l| match lanes[*l].accel.plan_key() {
                Some(k) => (0u8, k),
                None => (1u8, 0),
            });
            sc.singles.clear();
            sc.batchable.clear();
            for &l in &sc.group_members[gi] {
                // singles: edge-conditioned lanes (edge inputs are only
                // compiled at batch 1) and CacheWarm capture lanes — a
                // replay whose next fresh directive is token-pruned or
                // shallow needs this execution's aux features, which
                // bucketed launches cannot slice per lane
                if lanes[l].req.edge.is_some() || lanes[l].accel.wants_aux_capture(i) {
                    sc.singles.push(l);
                } else {
                    sc.batchable.push(l);
                }
            }
            for &l in &sc.singles {
                self.run_lane_single(&mut lanes[l], i)?;
            }
            let mut at = 0usize;
            for &chunk in &sc.splits[sc.batchable.len()] {
                if chunk == 1 {
                    let l = sc.batchable[at];
                    at += 1;
                    self.run_lane_single(&mut lanes[l], i)?;
                    continue;
                }
                let lo = at;
                at += chunk;
                self.run_lane_bucket(lanes, &sc.batchable[lo..at], i, &sc.bucket_variants)?;
            }
        }
        Ok(())
    }

    /// Single-lane full execution: the same code path as the Full arm of
    /// [`Pipeline::generate`] (including deep/caches capture), so a lane
    /// executed alone is bit-identical to sequential generation.
    fn run_lane_single(&self, lane: &mut Lane, i: usize) -> Result<()> {
        let t_norm = lane.solver.t_norm(i);
        // xtask: allow(panic): persistent x slot — Some for the whole run
        lane.args.x.as_mut().expect("persistent x slot").copy_from(&lane.x);
        lane.args.t = t_norm as f32;
        self.backend.run_into(
            "full",
            &lane.args,
            &mut lane.m_out,
            Some(lane.deep.slot()),
            Some(lane.caches.slot()),
        )?;
        // single full executions refresh the aux features their signature
        // declares (empty signatures follow the run_into contract: full
        // emits both); an unemitted slot keeps its previous validity
        let info = self.backend.info();
        if info.emits_output("full", "deep") {
            lane.deep.mark_valid();
        }
        if info.emits_output("full", "caches") {
            lane.caches.mark_valid();
        }
        lane.executed = true;
        Ok(())
    }

    /// Bucketed full execution of `sub` (>= 2 lanes, one guidance value):
    /// lane states and conds are gathered row-wise into arena-pooled
    /// `[chunk, ...]` buffers, the compiled `full_b{chunk}` variant runs
    /// into a pooled output buffer, and rows scatter back into each lane's
    /// `m_out` in place. All three buffers return to the arena, so the
    /// steady state allocates nothing.
    fn run_lane_bucket(
        &self,
        lanes: &mut [Lane],
        sub: &[usize],
        i: usize,
        bucket_variants: &[(usize, String)],
    ) -> Result<()> {
        let chunk = sub.len();
        let info = self.backend.info();
        let [h, w, c] = info.img;
        let t_norm = lanes[sub[0]].solver.t_norm(i);
        let gs = lanes[sub[0]].req.guidance;
        let variant = bucket_variants
            .iter()
            .find(|(n, _)| *n == chunk)
            .map(|(_, v)| v.as_str());
        let variant = match variant {
            Some(v) => v,
            None => anyhow::bail!("no compiled bucket variant for a {chunk}-lane chunk"),
        };
        let mut xb = self.arena.checkout(&[chunk, h, w, c]);
        let mut cb = self.arena.checkout(&[chunk, info.cond_dim]);
        for (k, &l) in sub.iter().enumerate() {
            view::copy_into_row(&mut xb, k, &lanes[l].x);
            view::copy_into_row(&mut cb, k, &lanes[l].req.cond);
        }
        let mut out_b = self.arena.checkout(&[chunk, h, w, c]);
        let mut args = ModelArgs {
            x: Some(xb),
            t: t_norm as f32,
            cond: Some(cb),
            gs,
            ..Default::default()
        };
        let run = self.backend.run_into(variant, &args, &mut out_b, None, None);
        // gather buffers go back to the pool whatever happened
        self.arena.release_opt(args.x.take());
        self.arena.release_opt(args.cond.take());
        match run {
            Ok(()) => {}
            Err(e) => {
                self.arena.release(out_b);
                return Err(e);
            }
        }
        for (k, &l) in sub.iter().enumerate() {
            let lane = &mut lanes[l];
            view::copy_from_row(&mut lane.m_out, &out_b, k);
            lane.executed = true;
            // batched aux layouts are not per-lane sliceable: mark the
            // features stale rather than feed them to Shallow/Prune — the
            // buffers stay retained for the next single's in-place refill
            lane.deep.invalidate();
            lane.caches.invalidate();
        }
        self.arena.release(out_b);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::NoAccel;
    use crate::runtime::mock::GmBackend;
    use crate::runtime::ModelBackend;
    use crate::sada::Sada;
    use crate::solvers::SolverKind;
    use crate::testutil::{check, UsizeIn};

    fn reqs_for(n: usize, steps: usize, seed: u64) -> Vec<GenRequest> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n)
            .map(|k| GenRequest {
                cond: Tensor::from_rng(&mut rng, &[1, 32]),
                seed: rng.below(10_000),
                guidance: [0.0f32, 2.0, 5.0][k % 3],
                steps,
                edge: None,
            })
            .collect()
    }

    #[test]
    fn property_noaccel_lanes_bit_identical_to_sequential() {
        // any seed/batch mix, with and without compiled batch buckets
        check(5, 10, &UsizeIn(1, 6), |b| {
            for bucketed in [false, true] {
                let backend = if bucketed {
                    GmBackend::with_batch_buckets(3, &[2, 4])
                } else {
                    GmBackend::new(3)
                };
                let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
                let reqs = reqs_for(*b, 8, *b as u64 * 31 + 7);
                let proto: &dyn Accelerator = &NoAccel;
                let lanes = pipe
                    .generate_lanes(&reqs, proto)
                    .map_err(|e| format!("lane engine failed: {e:#}"))?;
                for (k, (lane, req)) in lanes.iter().zip(&reqs).enumerate() {
                    let solo = pipe
                        .generate(req, &mut NoAccel)
                        .map_err(|e| format!("sequential failed: {e:#}"))?;
                    if lane.image.data() != solo.image.data() {
                        return Err(format!(
                            "lane {k} (bucketed={bucketed}, b={b}) not bit-identical"
                        ));
                    }
                    if lane.stats.nfe != solo.stats.nfe {
                        return Err(format!("lane {k} nfe {} != {}", lane.stats.nfe, solo.stats.nfe));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_exact_bucket_required_and_buckets_shrink_model_calls() {
        // 5 lanes with only full_b2 compiled: chunks [2, 2, 1] per step
        let backend = GmBackend::with_batch_buckets(4, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let steps = 6;
        let reqs: Vec<GenRequest> = reqs_for(5, steps, 11)
            .into_iter()
            .map(|mut r| {
                r.guidance = 3.0; // one guidance group: maximal gathering
                r
            })
            .collect();
        backend.reset_nfe();
        let proto: &dyn Accelerator = &NoAccel;
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        assert_eq!(out.len(), 5);
        // 3 launches per step instead of 5 sequential calls
        assert_eq!(backend.nfe(), steps * 3);
        for lane in &out {
            assert_eq!(lane.stats.nfe, steps);
        }
    }

    #[test]
    fn duplicate_lanes_are_deterministic_and_divergent_lanes_decide_independently() {
        // two identical lanes must produce identical traces; across GM
        // landscapes, a smooth (gs=0) and a strongly-guided (gs=8) lane
        // must make different SADA skip decisions in the same batch
        let steps = 50;
        let mut any_diverged = false;
        for seed in 0..12u64 {
            let backend = GmBackend::with_batch_buckets(seed + 1, &[2]);
            let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
            let mut rng = crate::rng::Rng::new(900 + seed);
            let smooth = GenRequest {
                cond: Tensor::zeros(&[1, 32]),
                seed: 7,
                guidance: 0.0,
                steps,
                edge: None,
            };
            let jagged = GenRequest {
                cond: Tensor::from_rng(&mut rng, &[1, 32]),
                seed: 8 + seed,
                guidance: 8.0,
                steps,
                edge: None,
            };
            let proto = Sada::with_default(backend.info(), steps);
            let proto: &dyn Accelerator = &proto;
            let twin = pipe
                .generate_lanes(&[smooth.clone(), smooth.clone()], proto)
                .unwrap();
            assert_eq!(
                twin[0].stats.mode_trace(),
                twin[1].stats.mode_trace(),
                "identical lanes must decide identically"
            );
            assert_eq!(twin[0].image.data(), twin[1].image.data());
            let pair = pipe.generate_lanes(&[smooth, jagged], proto).unwrap();
            if pair[0].stats.mode_trace() != pair[1].stats.mode_trace() {
                any_diverged = true;
                break;
            }
        }
        assert!(
            any_diverged,
            "divergent trajectories never produced different per-lane skip decisions (12 seeds)"
        );
    }

    #[test]
    fn per_lane_beats_lockstep_on_some_divergent_workload() {
        // the serving claim in miniature: independent lanes skip more than
        // a conservative global decision on at least one divergent batch
        let steps = 50;
        let mut found = false;
        for seed in 0..12u64 {
            let backend = GmBackend::with_batch_buckets(seed + 2, &[2, 4]);
            let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
            let reqs = reqs_for(4, steps, 70 + seed);
            let proto = Sada::with_default(backend.info(), steps);
            let proto: &dyn Accelerator = &proto;
            let per_lane = pipe.generate_lanes(&reqs, proto).unwrap();
            let lockstep = pipe
                .generate_lanes_mode(&reqs, proto, LaneMode::Lockstep)
                .unwrap();
            let nfe = |rs: &[GenResult]| rs.iter().map(|r| r.stats.nfe).sum::<usize>();
            if nfe(&per_lane) < nfe(&lockstep) {
                found = true;
                break;
            }
        }
        assert!(found, "per-lane NFE never beat lockstep across 12 workloads");
    }

    #[test]
    fn lane_batch_of_one_matches_generate() {
        let backend = GmBackend::new(6);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let reqs = reqs_for(1, 9, 5);
        let proto: &dyn Accelerator = &NoAccel;
        let lane = pipe.generate_lanes(&reqs, proto).unwrap();
        let solo = pipe.generate(&reqs[0], &mut NoAccel).unwrap();
        assert_eq!(lane[0].image.data(), solo.image.data());
        assert_eq!(lane[0].stats.mode_trace(), solo.stats.mode_trace());
    }

    #[test]
    fn lane_engine_rejects_bad_batches() {
        let backend = GmBackend::new(6);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let proto: &dyn Accelerator = &NoAccel;
        assert!(pipe.generate_lanes(&[], proto).is_err());
        let mut reqs = reqs_for(2, 5, 1);
        reqs[1].steps = 9;
        assert!(pipe.generate_lanes(&reqs, proto).is_err());
    }

    #[test]
    fn mixed_guidance_lanes_execute_in_separate_sub_batches() {
        // two guidance groups over full_b2: every lane still matches its
        // own sequential run exactly
        let backend = GmBackend::with_batch_buckets(8, &[2]);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let mut reqs = reqs_for(4, 7, 21);
        reqs[0].guidance = 1.0;
        reqs[1].guidance = 4.0;
        reqs[2].guidance = 1.0;
        reqs[3].guidance = 4.0;
        let proto: &dyn Accelerator = &NoAccel;
        let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
        for (lane, req) in lanes.iter().zip(&reqs) {
            let solo = pipe.generate(req, &mut NoAccel).unwrap();
            assert_eq!(lane.image.data(), solo.image.data());
        }
    }

    #[test]
    fn deepcache_lanes_keep_shallow_acceleration_without_buckets() {
        // no compiled buckets: every full run is a single, so lanes track
        // deep features lane-locally and the shallow path survives
        // batching — bit-identical to per-request sequential generation
        let backend = GmBackend::new(11);
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let reqs = reqs_for(2, 12, 44);
        let proto = crate::baselines::DeepCache::new(3);
        let proto: &dyn Accelerator = &proto;
        let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
        for (lane, req) in lanes.iter().zip(&reqs) {
            assert!(
                lane.stats.count(crate::pipeline::StepMode::Shallow) > 4,
                "shallow discount lost under batching: trace={}",
                lane.stats.mode_trace()
            );
            let solo = pipe
                .generate(req, &mut crate::baselines::DeepCache::new(3))
                .unwrap();
            assert_eq!(lane.image.data(), solo.image.data());
            assert_eq!(lane.stats.mode_trace(), solo.stats.mode_trace());
        }
    }

    #[test]
    fn fn_factory_builds_heterogeneous_lanes() {
        let backend = GmBackend::new(9);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let steps = 30;
        let reqs = reqs_for(2, steps, 33);
        let info = backend.info().clone();
        let factory = FnFactory(move |lane: usize| -> Box<dyn Accelerator> {
            if lane == 0 {
                Box::new(NoAccel)
            } else {
                Box::new(Sada::with_default(&info, steps))
            }
        });
        let lanes = pipe.generate_lanes(&reqs, &factory).unwrap();
        assert_eq!(lanes[0].stats.accel, "baseline");
        assert_eq!(lanes[1].stats.accel, "sada");
        assert_eq!(lanes[0].stats.nfe, steps);
    }
}
