//! SADA: Stability-guided Adaptive Diffusion Acceleration.
//!
//! A serving framework reproducing Jiang et al., ICML 2025 in the mandated
//! three-layer architecture: this rust crate is Layer 3 (the request path:
//! router, batcher, SADA scheduler, ODE solvers), executing Layer-2 JAX
//! models (with Layer-1 Pallas kernels) that were AOT-lowered to HLO text
//! under `artifacts/` by `make artifacts`. Python never runs at request time.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`tensor`], [`rng`] — host tensor math + seeded PRNG substrate
//! * [`runtime`] — PJRT client, artifact registry, executable wrappers
//! * [`solvers`] — DDPM schedule, Euler/DDIM, DPM-Solver++(2M), flow Euler
//! * [`sada`] — the paper's contribution: stability criterion, AM-3
//!   step-wise pruning, multistep Lagrange reconstruction, token-wise masks
//! * [`baselines`] — DeepCache / AdaptiveDiffusion / TeaCache comparators
//! * [`pipeline`] — generation pipelines gluing model+solver+accelerator
//! * [`plancache`] — skip-plan cache: trajectory signatures, sharded LRU
//!   plan store, speculative warm-start replay with divergence fallback
//! * [`metrics`] — PSNR / LPIPS-RC / FID-RC quality metrics
//! * [`coordinator`] — serving front-end: router, dynamic batcher, engine
//! * [`workload`] — prompt bank + arrival-trace generators
//! * [`exp`] — experiment harnesses regenerating every paper table/figure

pub mod analysis;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod plancache;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sada;
pub mod solvers;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod workload;

pub use tensor::Tensor;
