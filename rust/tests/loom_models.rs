//! Loom models of the two concurrency kernels the serving path leans on:
//! the bounded condvar work queue (`coordinator::server::WorkQueue`) and a
//! plan-store shard (`plancache::store`). The models restate the algorithms
//! with loom primitives — loom then explores every interleaving and fails
//! on deadlock, lost wakeup, or a violated assertion.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` with the `loom` crate
//! available (the CI job probes for it and skips otherwise); a plain
//! `cargo test` ignores this file entirely.

#![cfg(loom)]

use std::collections::VecDeque;

use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// The WorkQueue algorithm, verbatim at model scale: bounded FIFO, two
/// condvars (ready / free), close() wakes both sides.
struct BoundedQueue {
    state: Mutex<(VecDeque<u32>, bool)>,
    cv_ready: Condvar,
    cv_free: Condvar,
    cap: usize,
}

impl BoundedQueue {
    fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new((VecDeque::new(), false)),
            cv_ready: Condvar::new(),
            cv_free: Condvar::new(),
            cap,
        }
    }

    fn push(&self, v: u32) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= self.cap && !st.1 {
            st = self.cv_free.wait(st).unwrap();
        }
        if st.1 {
            return; // closed: drop, reply channels fail fast
        }
        st.0.push_back(v);
        self.cv_ready.notify_one();
    }

    fn pop(&self) -> Option<u32> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.0.pop_front() {
                self.cv_free.notify_one();
                return Some(v);
            }
            if st.1 {
                return None;
            }
            st = self.cv_ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv_ready.notify_all();
        self.cv_free.notify_all();
    }
}

#[test]
fn bounded_queue_delivers_everything_pushed_before_close() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let p1 = {
            let q = q.clone();
            thread::spawn(move || q.push(1))
        };
        let p2 = {
            let q = q.clone();
            thread::spawn(move || q.push(2))
        };
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        p1.join().unwrap();
        p2.join().unwrap();
        q.close();
        let got = c.join().unwrap();
        // close() happened after both pushes returned, so with cap 1 the
        // consumer must still drain both items in FIFO-per-producer order
        assert_eq!(got.len(), 2, "lost item: {got:?}");
        assert_eq!(got.iter().sum::<u32>(), 3, "wrong items: {got:?}");
    });
}

#[test]
fn closed_queue_drops_late_pushes_and_unblocks_consumer() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut n = 0u32;
                while q.pop().is_some() {
                    n += 1;
                }
                n
            })
        };
        let p = {
            let q = q.clone();
            thread::spawn(move || q.push(7))
        };
        q.close();
        p.join().unwrap(); // a late push must not deadlock on a full queue
        let n = c.join().unwrap();
        assert!(n <= 1, "more items than were pushed");
    });
}

/// One plan-store shard: last-writer-wins map + monotone LRU tick under a
/// single mutex (the real store stripes these; cross-shard order is covered
/// by the lock-order pass, intra-shard coherence by this model).
#[test]
fn plan_shard_concurrent_insert_get_is_coherent() {
    loom::model(|| {
        let shard = Arc::new(Mutex::new((std::collections::HashMap::new(), 0u64)));
        let w1 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 10u64);
            })
        };
        let w2 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 20u64);
            })
        };
        let r = {
            let s = shard.clone();
            thread::spawn(move || {
                let g = s.lock().unwrap();
                g.0.get(&0).copied()
            })
        };
        w1.join().unwrap();
        w2.join().unwrap();
        let seen = r.join().unwrap();
        assert!(matches!(seen, None | Some(10) | Some(20)), "torn read: {seen:?}");
        let g = shard.lock().unwrap();
        assert_eq!(g.1, 2, "LRU tick must count both writers");
        assert!(matches!(g.0.get(&0), Some(&10) | Some(&20)));
    });
}
