//! Loom models of the concurrency kernels the serving path leans on: the
//! bounded condvar work queue (`coordinator::server::WorkQueue`), its
//! mid-flight steal extension for continuous batching
//! (`WorkQueue::steal_compatible`), and a plan-store shard
//! (`plancache::store`). The models restate the algorithms
//! with loom primitives — loom then explores every interleaving and fails
//! on deadlock, lost wakeup, or a violated assertion.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` with the `loom` crate
//! available (the CI job probes for it and skips otherwise); a plain
//! `cargo test` ignores this file entirely.

#![cfg(loom)]

use std::collections::VecDeque;

use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// The WorkQueue algorithm, verbatim at model scale: bounded FIFO, two
/// condvars (ready / free), close() wakes both sides.
struct BoundedQueue {
    state: Mutex<(VecDeque<u32>, bool)>,
    cv_ready: Condvar,
    cv_free: Condvar,
    cap: usize,
}

impl BoundedQueue {
    fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new((VecDeque::new(), false)),
            cv_ready: Condvar::new(),
            cv_free: Condvar::new(),
            cap,
        }
    }

    fn push(&self, v: u32) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= self.cap && !st.1 {
            st = self.cv_free.wait(st).unwrap();
        }
        if st.1 {
            return; // closed: drop, reply channels fail fast
        }
        st.0.push_back(v);
        self.cv_ready.notify_one();
    }

    fn pop(&self) -> Option<u32> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.0.pop_front() {
                self.cv_free.notify_one();
                return Some(v);
            }
            if st.1 {
                return None;
            }
            st = self.cv_ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv_ready.notify_all();
        self.cv_free.notify_all();
    }
}

#[test]
fn bounded_queue_delivers_everything_pushed_before_close() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let p1 = {
            let q = q.clone();
            thread::spawn(move || q.push(1))
        };
        let p2 = {
            let q = q.clone();
            thread::spawn(move || q.push(2))
        };
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        p1.join().unwrap();
        p2.join().unwrap();
        q.close();
        let got = c.join().unwrap();
        // close() happened after both pushes returned, so with cap 1 the
        // consumer must still drain both items in FIFO-per-producer order
        assert_eq!(got.len(), 2, "lost item: {got:?}");
        assert_eq!(got.iter().sum::<u32>(), 3, "wrong items: {got:?}");
    });
}

#[test]
fn closed_queue_drops_late_pushes_and_unblocks_consumer() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut n = 0u32;
                while q.pop().is_some() {
                    n += 1;
                }
                n
            })
        };
        let p = {
            let q = q.clone();
            thread::spawn(move || q.push(7))
        };
        q.close();
        p.join().unwrap(); // a late push must not deadlock on a full queue
        let n = c.join().unwrap();
        assert!(n <= 1, "more items than were pushed");
    });
}

/// The continuous-serving extension of the WorkQueue: a worker with `free`
/// lane slots steals requests out of the front queued batch mid-flight
/// (`WorkQueue::steal_compatible`). The backpressure contract is that the
/// slot-free signal (`cv_free`) fires exactly when a whole queued item is
/// consumed — a partial steal reinserts the remainder and must NOT wake
/// pushers (the slot is still held). Items model batches of request ids.
struct StealQueue {
    state: Mutex<(VecDeque<Vec<u32>>, bool)>,
    cv_ready: Condvar,
    cv_free: Condvar,
    cap: usize,
}

impl StealQueue {
    fn new(cap: usize) -> Self {
        StealQueue {
            state: Mutex::new((VecDeque::new(), false)),
            cv_ready: Condvar::new(),
            cv_free: Condvar::new(),
            cap,
        }
    }

    fn push(&self, batch: Vec<u32>) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= self.cap && !st.1 {
            st = self.cv_free.wait(st).unwrap();
        }
        if st.1 {
            return; // closed: drop, reply channels fail fast
        }
        st.0.push_back(batch);
        self.cv_ready.notify_one();
    }

    fn pop(&self) -> Option<Vec<u32>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.0.pop_front() {
                self.cv_free.notify_one();
                return Some(v);
            }
            if st.1 {
                return None;
            }
            st = self.cv_ready.wait(st).unwrap();
        }
    }

    /// `WorkQueue::steal_compatible` at model scale: take up to `free`
    /// requests from the front batch; notify `cv_free` only when the batch
    /// is fully consumed, otherwise reinsert the remainder in place.
    fn steal(&self, free: usize) -> Vec<u32> {
        let mut out = Vec::new();
        if free == 0 {
            return out;
        }
        let mut st = self.state.lock().unwrap();
        if let Some(mut item) = st.0.pop_front() {
            let n = free.min(item.len());
            out.extend(item.drain(..n));
            if item.is_empty() {
                self.cv_free.notify_one();
            } else {
                st.0.push_front(item);
            }
        }
        out
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv_ready.notify_all();
        self.cv_free.notify_all();
    }
}

#[test]
fn freed_slot_steal_wakes_blocked_pusher_even_racing_close() {
    loom::model(|| {
        let q = Arc::new(StealQueue::new(1));
        q.push(vec![1, 2]); // fills the single slot before any thread starts
        let p = {
            let q = q.clone();
            thread::spawn(move || q.push(vec![3])) // blocks on cv_free
        };
        let s = {
            let q = q.clone();
            thread::spawn(move || {
                // partial steal: remainder reinserted, slot still held, the
                // blocked pusher must NOT be woken by this call
                let mut got = q.steal(1);
                // consuming steal: the batch empties, cv_free fires
                got.extend(q.steal(1));
                got
            })
        };
        let c = {
            let q = q.clone();
            thread::spawn(move || q.close())
        };
        let got = s.join().unwrap();
        c.join().unwrap();
        // the hazard under test: the pusher must terminate in EVERY
        // interleaving of {steal's free signal, close} — a lost wakeup here
        // deadlocks and loom flags it
        p.join().unwrap();
        assert_eq!(got, vec![1, 2], "steal must drain the seed batch in order");
        let mut rest = Vec::new();
        while let Some(b) = q.pop() {
            rest.extend(b);
        }
        // the late push either landed intact (woken by the free slot before
        // close) or was dropped whole at close — never a torn batch
        assert!(rest == vec![3] || rest.is_empty(), "torn batch: {rest:?}");
    });
}

/// One plan-store shard: last-writer-wins map + monotone LRU tick under a
/// single mutex (the real store stripes these; cross-shard order is covered
/// by the lock-order pass, intra-shard coherence by this model).
#[test]
fn plan_shard_concurrent_insert_get_is_coherent() {
    loom::model(|| {
        let shard = Arc::new(Mutex::new((std::collections::HashMap::new(), 0u64)));
        let w1 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 10u64);
            })
        };
        let w2 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 20u64);
            })
        };
        let r = {
            let s = shard.clone();
            thread::spawn(move || {
                let g = s.lock().unwrap();
                g.0.get(&0).copied()
            })
        };
        w1.join().unwrap();
        w2.join().unwrap();
        let seen = r.join().unwrap();
        assert!(matches!(seen, None | Some(10) | Some(20)), "torn read: {seen:?}");
        let g = shard.lock().unwrap();
        assert_eq!(g.1, 2, "LRU tick must count both writers");
        assert!(matches!(g.0.get(&0), Some(&10) | Some(&20)));
    });
}
