//! Loom models of the concurrency kernels the serving path leans on: the
//! bounded condvar work queue (`coordinator::server::WorkQueue`), its
//! mid-flight steal extension for continuous batching
//! (`WorkQueue::steal_compatible`), and a plan-store shard
//! (`plancache::store`). The models restate the algorithms
//! with loom primitives — loom then explores every interleaving and fails
//! on deadlock, lost wakeup, or a violated assertion.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` with the `loom` crate
//! available (the CI job probes for it and skips otherwise); a plain
//! `cargo test` ignores this file entirely.

#![cfg(loom)]

use std::collections::VecDeque;

use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// The WorkQueue algorithm, verbatim at model scale: bounded FIFO, two
/// condvars (ready / free), close() wakes both sides.
struct BoundedQueue {
    state: Mutex<(VecDeque<u32>, bool)>,
    cv_ready: Condvar,
    cv_free: Condvar,
    cap: usize,
}

impl BoundedQueue {
    fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new((VecDeque::new(), false)),
            cv_ready: Condvar::new(),
            cv_free: Condvar::new(),
            cap,
        }
    }

    fn push(&self, v: u32) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= self.cap && !st.1 {
            st = self.cv_free.wait(st).unwrap();
        }
        if st.1 {
            return; // closed: drop, reply channels fail fast
        }
        st.0.push_back(v);
        self.cv_ready.notify_one();
    }

    fn pop(&self) -> Option<u32> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.0.pop_front() {
                self.cv_free.notify_one();
                return Some(v);
            }
            if st.1 {
                return None;
            }
            st = self.cv_ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv_ready.notify_all();
        self.cv_free.notify_all();
    }
}

#[test]
fn bounded_queue_delivers_everything_pushed_before_close() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let p1 = {
            let q = q.clone();
            thread::spawn(move || q.push(1))
        };
        let p2 = {
            let q = q.clone();
            thread::spawn(move || q.push(2))
        };
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        p1.join().unwrap();
        p2.join().unwrap();
        q.close();
        let got = c.join().unwrap();
        // close() happened after both pushes returned, so with cap 1 the
        // consumer must still drain both items in FIFO-per-producer order
        assert_eq!(got.len(), 2, "lost item: {got:?}");
        assert_eq!(got.iter().sum::<u32>(), 3, "wrong items: {got:?}");
    });
}

#[test]
fn closed_queue_drops_late_pushes_and_unblocks_consumer() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let c = {
            let q = q.clone();
            thread::spawn(move || {
                let mut n = 0u32;
                while q.pop().is_some() {
                    n += 1;
                }
                n
            })
        };
        let p = {
            let q = q.clone();
            thread::spawn(move || q.push(7))
        };
        q.close();
        p.join().unwrap(); // a late push must not deadlock on a full queue
        let n = c.join().unwrap();
        assert!(n <= 1, "more items than were pushed");
    });
}

/// The continuous-serving extension of the WorkQueue: a worker with `free`
/// lane slots steals requests out of the front queued batch mid-flight
/// (`WorkQueue::steal_compatible`). The backpressure contract is that the
/// slot-free signal (`cv_free`) fires exactly when a whole queued item is
/// consumed — a partial steal reinserts the remainder and must NOT wake
/// pushers (the slot is still held). Items model batches of request ids.
struct StealQueue {
    state: Mutex<(VecDeque<Vec<u32>>, bool)>,
    cv_ready: Condvar,
    cv_free: Condvar,
    cap: usize,
}

impl StealQueue {
    fn new(cap: usize) -> Self {
        StealQueue {
            state: Mutex::new((VecDeque::new(), false)),
            cv_ready: Condvar::new(),
            cv_free: Condvar::new(),
            cap,
        }
    }

    fn push(&self, batch: Vec<u32>) {
        let mut st = self.state.lock().unwrap();
        while st.0.len() >= self.cap && !st.1 {
            st = self.cv_free.wait(st).unwrap();
        }
        if st.1 {
            return; // closed: drop, reply channels fail fast
        }
        st.0.push_back(batch);
        self.cv_ready.notify_one();
    }

    fn pop(&self) -> Option<Vec<u32>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.0.pop_front() {
                self.cv_free.notify_one();
                return Some(v);
            }
            if st.1 {
                return None;
            }
            st = self.cv_ready.wait(st).unwrap();
        }
    }

    /// `WorkQueue::steal_compatible` at model scale: take up to `free`
    /// requests from the front batch; notify `cv_free` only when the batch
    /// is fully consumed, otherwise reinsert the remainder in place.
    fn steal(&self, free: usize) -> Vec<u32> {
        let mut out = Vec::new();
        if free == 0 {
            return out;
        }
        let mut st = self.state.lock().unwrap();
        if let Some(mut item) = st.0.pop_front() {
            let n = free.min(item.len());
            out.extend(item.drain(..n));
            if item.is_empty() {
                self.cv_free.notify_one();
            } else {
                st.0.push_front(item);
            }
        }
        out
    }

    /// `WorkQueue::steal_scan` at model scale: take up to `free` requests
    /// across EVERY queued batch (queue order stands in for slack rank),
    /// remainders keep their positions, and each batch this scan empties
    /// fires `cv_free` exactly once — two emptied batches must wake two
    /// blocked pushers.
    fn steal_scan(&self, free: usize) -> Vec<u32> {
        let mut out = Vec::new();
        if free == 0 {
            return out;
        }
        let mut st = self.state.lock().unwrap();
        let mut i = 0;
        while i < st.0.len() && out.len() < free {
            let want = free - out.len();
            let item = &mut st.0[i];
            let n = want.min(item.len());
            out.extend(item.drain(..n));
            i += 1;
        }
        let mut j = 0;
        while j < st.0.len() {
            if st.0[j].is_empty() {
                st.0.remove(j);
                self.cv_free.notify_one();
            } else {
                j += 1;
            }
        }
        out
    }

    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv_ready.notify_all();
        self.cv_free.notify_all();
    }
}

#[test]
fn freed_slot_steal_wakes_blocked_pusher_even_racing_close() {
    loom::model(|| {
        let q = Arc::new(StealQueue::new(1));
        q.push(vec![1, 2]); // fills the single slot before any thread starts
        let p = {
            let q = q.clone();
            thread::spawn(move || q.push(vec![3])) // blocks on cv_free
        };
        let s = {
            let q = q.clone();
            thread::spawn(move || {
                // partial steal: remainder reinserted, slot still held, the
                // blocked pusher must NOT be woken by this call
                let mut got = q.steal(1);
                // consuming steal: the batch empties, cv_free fires
                got.extend(q.steal(1));
                got
            })
        };
        let c = {
            let q = q.clone();
            thread::spawn(move || q.close())
        };
        let got = s.join().unwrap();
        c.join().unwrap();
        // the hazard under test: the pusher must terminate in EVERY
        // interleaving of {steal's free signal, close} — a lost wakeup here
        // deadlocks and loom flags it
        p.join().unwrap();
        assert_eq!(got, vec![1, 2], "steal must drain the seed batch in order");
        let mut rest = Vec::new();
        while let Some(b) = q.pop() {
            rest.extend(b);
        }
        // the late push either landed intact (woken by the free slot before
        // close) or was dropped whole at close — never a torn batch
        assert!(rest == vec![3] || rest.is_empty(), "torn batch: {rest:?}");
    });
}

#[test]
fn multi_batch_steal_scan_wakes_every_pusher_it_unblocks() {
    loom::model(|| {
        // cap 2, both slots filled with singleton batches before any
        // thread starts; two pushers block on cv_free
        let q = Arc::new(StealQueue::new(2));
        q.push(vec![1]);
        q.push(vec![2]);
        let p1 = {
            let q = q.clone();
            thread::spawn(move || q.push(vec![3]))
        };
        let p2 = {
            let q = q.clone();
            thread::spawn(move || q.push(vec![4]))
        };
        let s = {
            let q = q.clone();
            // one scan fills two free lane slots from two different
            // batches, emptying both — it must fire cv_free twice, or one
            // pusher sleeps forever and loom flags the deadlock
            thread::spawn(move || q.steal_scan(2))
        };
        let got = s.join().unwrap();
        let c = {
            let q = q.clone();
            thread::spawn(move || q.close())
        };
        c.join().unwrap();
        p1.join().unwrap();
        p2.join().unwrap();
        assert_eq!(got, vec![1, 2], "scan must drain both seed batches in rank order");
        let mut rest = Vec::new();
        while let Some(b) = q.pop() {
            rest.extend(b);
        }
        // late pushes either landed whole or were dropped whole at close
        rest.sort_unstable();
        assert!(
            rest == vec![3, 4] || rest == vec![3] || rest == vec![4] || rest.is_empty(),
            "torn batch: {rest:?}"
        );
    });
}

#[test]
fn preempt_release_steal_resume_handoff_terminates_and_resumes() {
    loom::model(|| {
        // The SlackPreempt slot handoff: a saturated engine parks a lane
        // checkpoint (slot freed), steals the urgent queued request into
        // the slot, and resumes the parked lane once the slot frees
        // again. (free_slots, parked, urgent_served) under one mutex
        // models the engine's slot accounting; the queue models the
        // urgent request's path in. The hazards: the urgent push racing
        // the steal/close must terminate, and the parked checkpoint must
        // be resumed on every path where the engine keeps running.
        let q = Arc::new(StealQueue::new(1));
        let slots = Arc::new(Mutex::new((0usize, false, false))); // (free, parked, served)
        let pusher = {
            let q = q.clone();
            thread::spawn(move || q.push(vec![9])) // the urgent request
        };
        let engine = {
            let q = q.clone();
            let slots = slots.clone();
            thread::spawn(move || {
                // preempt: park the running lane, freeing its slot
                {
                    let mut s = slots.lock().unwrap();
                    s.1 = true;
                    s.0 += 1;
                }
                // steal into the freed slot (may race the push; an empty
                // steal means the urgent request was not queued yet — the
                // engine loops, modeled as a second scan after the push
                // is known complete via join below)
                let mut got = q.steal_scan(1);
                if let Some(id) = got.pop() {
                    assert_eq!(id, 9);
                    let mut s = slots.lock().unwrap();
                    s.0 -= 1; // urgent occupies the slot
                    s.2 = true;
                    s.0 += 1; // urgent completes, slot frees
                }
                // resume: the freed slot takes the parked checkpoint back
                let mut s = slots.lock().unwrap();
                if s.0 > 0 && s.1 {
                    s.0 -= 1;
                    s.1 = false;
                }
            })
        };
        pusher.join().unwrap();
        engine.join().unwrap();
        // drain whatever the steal missed, then re-run the engine's
        // resume obligation: a parked lane is never abandoned
        let leftover = q.steal_scan(1);
        q.close();
        let s = slots.lock().unwrap();
        assert!(!s.1, "parked checkpoint must be resumed, not abandoned");
        if s.2 {
            assert!(leftover.is_empty(), "urgent request served exactly once");
        } else {
            assert_eq!(leftover, vec![9], "unserved urgent request stays queued");
        }
    });
}

/// One plan-store shard: last-writer-wins map + monotone LRU tick under a
/// single mutex (the real store stripes these; cross-shard order is covered
/// by the lock-order pass, intra-shard coherence by this model).
#[test]
fn plan_shard_concurrent_insert_get_is_coherent() {
    loom::model(|| {
        let shard = Arc::new(Mutex::new((std::collections::HashMap::new(), 0u64)));
        let w1 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 10u64);
            })
        };
        let w2 = {
            let s = shard.clone();
            thread::spawn(move || {
                let mut g = s.lock().unwrap();
                g.1 += 1;
                g.0.insert(0u8, 20u64);
            })
        };
        let r = {
            let s = shard.clone();
            thread::spawn(move || {
                let g = s.lock().unwrap();
                g.0.get(&0).copied()
            })
        };
        w1.join().unwrap();
        w2.join().unwrap();
        let seen = r.join().unwrap();
        assert!(matches!(seen, None | Some(10) | Some(20)), "torn read: {seen:?}");
        let g = shard.lock().unwrap();
        assert_eq!(g.1, 2, "LRU tick must count both writers");
        assert!(matches!(g.0.get(&0), Some(&10) | Some(&20)));
    });
}
