//! Failure injection: the runtime and coordinator must fail loudly and
//! cleanly on corrupted artifacts, wrong shapes and bad configuration —
//! never with a segfault, hang, or silent wrong answer.

use std::fs;

use sada::runtime::{Manifest, ModelArgs, ModelBackend, Runtime};
use sada::tensor::Tensor;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sada_test_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_an_error() {
    let d = tmpdir("nomanifest");
    match Runtime::open(&d) {
        Ok(_) => panic!("opening an empty dir must fail"),
        Err(err) => assert!(format!("{err:#}").contains("manifest")),
    }
}

#[test]
fn corrupt_manifest_is_an_error() {
    let d = tmpdir("badjson");
    fs::write(d.join("manifest.json"), "{ not json").unwrap();
    assert!(Runtime::open(&d).is_err());
}

#[test]
fn manifest_missing_fields_is_an_error() {
    assert!(Manifest::parse(r#"{"schedule": {}}"#).is_err());
    assert!(Manifest::parse(r#"{"schedule": {"train_t": 10, "beta_start": 0.1, "beta_end": 0.2}}"#).is_err());
}

#[test]
fn missing_hlo_file_is_an_error() {
    let d = tmpdir("nohlo");
    fs::write(
        d.join("manifest.json"),
        r#"{
          "version": 1,
          "schedule": {"train_t": 1000, "beta_start": 0.0001, "beta_end": 0.02},
          "cond_dim": 32, "prune_buckets": [], "batch_buckets": [],
          "models": {"m": {
            "style": "unet", "predict": "eps", "img": [8,8,1], "patch": 2,
            "d": 16, "heads": 2, "n_tokens": 16, "n_blocks": 1,
            "has_control": false, "cond_dim": 32,
            "variants": {"full": {"file": "missing.hlo.txt", "kind": "full",
              "batch": 1, "n_keep": 0,
              "inputs": [{"name": "x", "shape": [1,8,8,1], "dtype": "f32"}],
              "outputs": [{"name": "out", "shape": [1,8,8,1], "dtype": "f32"}]}}
          }}
        }"#,
    )
    .unwrap();
    let rt = Runtime::open(&d).unwrap();
    let backend = rt.model_backend("m").unwrap();
    let err = backend
        .run("full", &ModelArgs { x: Some(Tensor::zeros(&[1, 8, 8, 1])), ..Default::default() })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("missing.hlo.txt") || msg.contains("parsing"), "{msg}");
}

#[test]
fn wrong_input_shape_is_rejected_before_execution() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts/ missing");
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let backend = rt.model_backend("sd2_tiny").unwrap();
    // wrong image shape: must be caught by the manifest shape check
    let err = backend
        .run(
            "full",
            &ModelArgs {
                x: Some(Tensor::zeros(&[1, 8, 8, 3])),
                t: 0.5,
                cond: Some(Tensor::zeros(&[1, 32])),
                gs: 1.0,
                ..Default::default()
            },
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape"));
    // wrong keep_idx length for the prune variant
    let err = backend
        .run(
            "prune50",
            &ModelArgs {
                x: Some(Tensor::zeros(&[1, 16, 16, 3])),
                t: 0.5,
                cond: Some(Tensor::zeros(&[1, 32])),
                gs: 1.0,
                keep_idx: Some(std::sync::Arc::new(sada::runtime::KeepMask {
                    variant: "prune50".into(),
                    keep_idx: vec![0, 1, 2],
                })),
                caches: Some(Tensor::zeros(&[5, 2, 64, 64])),
                ..Default::default()
            },
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("keep_idx"));
}

#[test]
fn missing_named_arg_is_an_error() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts/ missing");
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let err = backend
        .run("full", &ModelArgs { x: None, ..Default::default() })
        .unwrap_err();
    assert!(format!("{err:#}").contains("args.x"));
}

#[test]
fn unknown_variant_is_an_error() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts/ missing");
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let backend = rt.model_backend("sd2_tiny").unwrap();
    assert!(backend.run("bogus_variant", &ModelArgs::default()).is_err());
}
