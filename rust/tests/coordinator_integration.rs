//! Integration: the serving coordinator end-to-end over real artifacts.
//! Self-skips when artifacts/ has not been built.

use std::sync::mpsc;
use std::time::Instant;

use sada::coordinator::request::RequestId;
use sada::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
    }
    ok
}

fn submit_n(coord: &Coordinator, n: usize, steps: usize, accel: &str) -> mpsc::Receiver<sada::coordinator::ServeResponse> {
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), 32);
    let (tx, rx) = mpsc::channel();
    for i in 0..n {
        coord
            .submit(ServeRequest {
                id: RequestId(i as u64),
                model: "sd2_tiny".into(),
                cond: bank.get(i).clone(),
                seed: bank.seed_for(i),
                steps,
                guidance: 3.0,
                accel: accel.into(),
                slo_ms: None,
                variant_hint: None,
                step_budget: None,
                submitted_at: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
    }
    rx
}

#[test]
fn serves_all_requests_exactly_once() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        ..Default::default()
    })
    .unwrap();
    let n = 6;
    let rx = submit_n(&coord, n, 10, "sada");
    let mut ids: Vec<u64> = (0..n).map(|_| rx.recv().unwrap().id.0).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    coord.shutdown().unwrap();
}

#[test]
fn batches_form_under_load() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 200.0,
        ..Default::default()
    })
    .unwrap();
    // burst of 8 identical-class baseline requests: must batch > 1
    let rx = submit_n(&coord, 8, 10, "baseline");
    let mut max_batch = 0;
    for _ in 0..8 {
        max_batch = max_batch.max(rx.recv().unwrap().batch_size);
    }
    assert!(max_batch > 1, "no batching happened (max batch {max_batch})");
    coord.shutdown().unwrap();
}

#[test]
fn rejects_unknown_model_without_crashing() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        ..Default::default()
    })
    .unwrap();
    let (tx, rx) = mpsc::channel();
    coord
        .submit(ServeRequest {
            id: RequestId(99),
            model: "nope".into(),
            cond: sada::Tensor::zeros(&[1, 32]),
            seed: 0,
            steps: 10,
            guidance: 1.0,
            accel: "sada".into(),
            slo_ms: None,
            variant_hint: None,
            step_budget: None,
            submitted_at: Instant::now(),
            reply: tx,
        })
        .unwrap();
    // rejected: the reply channel is dropped without a response
    assert!(rx.recv().is_err());
    // the coordinator still serves subsequent valid requests
    let rx2 = submit_n(&coord, 2, 10, "baseline");
    assert!(rx2.recv().is_ok());
    assert!(rx2.recv().is_ok());
    coord.shutdown().unwrap();
}

#[test]
fn shutdown_drains_pending() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 500.0, // long deadline: requests are pending at shutdown
        ..Default::default()
    })
    .unwrap();
    let rx = submit_n(&coord, 3, 10, "baseline");
    coord.shutdown().unwrap(); // must flush before joining
    let mut got = 0;
    while rx.recv().is_ok() {
        got += 1;
    }
    assert_eq!(got, 3);
}

#[test]
fn mixed_models_route_to_correct_solvers() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into(), "flux_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        ..Default::default()
    })
    .unwrap();
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), 32);
    let (tx, rx) = mpsc::channel();
    for (i, model) in ["sd2_tiny", "flux_tiny", "sd2_tiny"].iter().enumerate() {
        coord
            .submit(ServeRequest {
                id: RequestId(i as u64),
                model: model.to_string(),
                cond: bank.get(i).clone(),
                seed: bank.seed_for(i),
                steps: 10,
                guidance: 2.0,
                accel: "baseline".into(),
                slo_ms: None,
                variant_hint: None,
                step_budget: None,
                submitted_at: Instant::now(),
                reply: tx.clone(),
            })
            .unwrap();
    }
    drop(tx);
    let mut got = 0;
    while let Ok(resp) = rx.recv() {
        assert!(resp.image.data().iter().all(|v| v.is_finite()));
        got += 1;
    }
    assert_eq!(got, 3);
    coord.shutdown().unwrap();
}

#[test]
fn pool_serves_all_requests_exactly_once_across_worker_counts() {
    if !have_artifacts() {
        return;
    }
    // the no-loss/no-duplication invariant must hold for every pool size
    for workers in [1usize, 2, 4] {
        let coord = Coordinator::start(CoordinatorConfig {
            models: vec!["sd2_tiny".into()],
            solver: SolverKind::DpmPP,
            max_wait_ms: 10.0,
            n_workers: workers,
            ..Default::default()
        })
        .unwrap();
        let n = 12;
        let rx = submit_n(&coord, n, 10, "sada");
        let mut ids: Vec<u64> = (0..n).map(|_| rx.recv().unwrap().id.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "workers={workers}");
        coord.shutdown().unwrap();
    }
}

#[test]
fn pool_attributes_every_batch_to_exactly_one_worker() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        n_workers: 4,
        ..Default::default()
    })
    .unwrap();
    let n = 16;
    let rx = submit_n(&coord, n, 10, "baseline");
    for _ in 0..n {
        rx.recv().unwrap();
    }
    let text = coord.metrics_text();
    let counter = |name: &str| -> u64 {
        text.lines()
            .find_map(|l| l.strip_prefix(&format!("sada_{name}_total ")))
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0)
    };
    let total = counter("batches_executed");
    assert!(total > 0, "{text}");
    let per_worker: u64 = (0..4).map(|i| counter(&format!("worker_{i}_batches"))).sum();
    assert_eq!(per_worker, total, "per-worker counters must sum to the pool total:\n{text}");
    assert!(text.contains("sada_batch_queue_wait_count"), "{text}");
    assert!(text.contains("sada_batch_execute_count"), "{text}");
    coord.shutdown().unwrap();
}

#[test]
fn shutdown_drains_pending_with_multiworker_pool() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 500.0, // long deadline: requests are pending at shutdown
        n_workers: 4,
        ..Default::default()
    })
    .unwrap();
    let rx = submit_n(&coord, 5, 10, "baseline");
    coord.shutdown().unwrap(); // must flush + drain the pool before joining
    let mut got = 0;
    while rx.recv().is_ok() {
        got += 1;
    }
    assert_eq!(got, 5);
}

#[test]
fn single_worker_completes_fifo_within_class() {
    if !have_artifacts() {
        return;
    }
    // with one engine worker, completion order within a compatibility
    // class must equal submission order (FIFO formation + serial execution)
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        n_workers: 1,
        ..Default::default()
    })
    .unwrap();
    let n = 10;
    let rx = submit_n(&coord, n, 10, "baseline");
    let ids: Vec<u64> = (0..n).map(|_| rx.recv().unwrap().id.0).collect();
    assert!(
        ids.windows(2).all(|w| w[0] < w[1]),
        "single-worker completion must be FIFO: {ids:?}"
    );
    coord.shutdown().unwrap();
}

#[test]
fn metrics_reflect_served_requests() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        models: vec!["sd2_tiny".into()],
        solver: SolverKind::DpmPP,
        max_wait_ms: 10.0,
        ..Default::default()
    })
    .unwrap();
    let rx = submit_n(&coord, 3, 10, "baseline");
    for _ in 0..3 {
        rx.recv().unwrap();
    }
    let text = coord.metrics_text();
    assert!(text.contains("sada_requests_accepted_total 3"), "{text}");
    assert!(text.contains("sada_e2e_latency_count 3"), "{text}");
    coord.shutdown().unwrap();
}
