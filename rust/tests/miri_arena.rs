//! Miri-targeted exercises of the unsafe-adjacent tensor machinery: row
//! views and copies (`tensor/view.rs`) and the arena / AuxSlot buffer
//! lifecycle (`tensor/arena.rs`). Everything here is deliberately tiny —
//! miri executes ~100x slower than native — and also runs as a normal
//! test, so the assertions are real invariants, not miri-only smoke.

use sada::tensor::arena::{AuxSlot, TensorArena};
use sada::tensor::view::{copy_from_row, copy_into_row, row_numel, RowsView};
use sada::tensor::Tensor;

fn filled(shape: &[usize], base: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    for (i, v) in t.data_mut().iter_mut().enumerate() {
        *v = base + i as f32;
    }
    t
}

#[test]
fn rows_view_aliases_exact_rows() {
    let t = filled(&[3, 4], 0.0);
    let v = RowsView::of(&t);
    assert_eq!(v.rows(), 3);
    assert_eq!(v.row_len(), 4);
    for r in 0..3 {
        let row = v.row(r);
        assert_eq!(row.len(), 4);
        assert_eq!(row[0], (r * 4) as f32);
        assert_eq!(row[3], (r * 4 + 3) as f32);
    }
    let d = v.row_dot(&v, 1);
    let expect: f64 = (4..8).map(|x| (x * x) as f64).sum();
    assert_eq!(d, expect);
}

#[test]
fn row_copies_roundtrip_without_touching_neighbours() {
    let mut batch = filled(&[3, 4], 100.0);
    let single = filled(&[1, 4], 0.0);
    assert_eq!(row_numel(&batch), 4);
    copy_into_row(&mut batch, 1, &single);
    // row 1 replaced, rows 0 and 2 untouched
    assert_eq!(&batch.data()[0..4], &[100.0, 101.0, 102.0, 103.0]);
    assert_eq!(&batch.data()[4..8], &[0.0, 1.0, 2.0, 3.0]);
    assert_eq!(&batch.data()[8..12], &[108.0, 109.0, 110.0, 111.0]);
    let mut out = Tensor::zeros(&[1, 4]);
    copy_from_row(&mut out, &batch, 2);
    assert_eq!(out.data(), &[108.0, 109.0, 110.0, 111.0]);
}

#[test]
fn arena_checkout_release_recycles_buffers_soundly() {
    let arena = TensorArena::new();
    let a = arena.checkout_zeroed(&[2, 3]);
    assert_eq!(a.data(), &[0.0; 6]);
    let mut b = arena.checkout(&[4]);
    for v in b.data_mut() {
        *v = 9.0;
    }
    arena.release(a);
    arena.release(b);
    // recycled buffer comes back with the same shape; zeroed checkout
    // must scrub the stale 9.0s
    let c = arena.checkout_zeroed(&[4]);
    assert_eq!(c.data(), &[0.0; 4]);
    arena.release(c);
    assert!(arena.pooled() >= 1);
    arena.clear();
    assert_eq!(arena.pooled(), 0);
}

#[test]
fn aux_slot_lifecycle_keeps_buffers_valid() {
    let arena = TensorArena::new();
    let mut slot = AuxSlot::new();
    assert!(!slot.is_valid());
    slot.ensure(&arena, &[2, 2]);
    assert!(!slot.is_valid(), "ensure leaves contents stale");
    if let Some(t) = slot.slot().as_mut() {
        for v in t.data_mut() {
            *v = 5.0;
        }
    }
    slot.mark_valid();
    assert!(slot.is_valid());
    // reshape releases the old buffer back to the arena, not to the void
    slot.ensure(&arena, &[3, 1]);
    assert!(!slot.is_valid());
    assert_eq!(slot.slot().as_ref().map(|t| t.shape().to_vec()), Some(vec![3, 1]));
    let taken = slot.take().expect("buffer present");
    assert_eq!(taken.shape(), &[3, 1]);
    slot.install(taken);
    assert!(slot.is_valid());
    slot.retire(&arena);
    assert!(!slot.is_valid());
    assert!(arena.pooled() >= 1, "retire must pool the buffer");
}
