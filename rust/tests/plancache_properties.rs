//! Skip-plan cache invariants (no artifacts needed: analytic GM backend).
//!
//! The load-bearing contract: speculative warm-start replay may only ever
//! *save* work, never change what a cacheless run would have produced —
//! an empty cache and an always-diverging cache are both bit-identical to
//! plain SADA (same images, same NFE), and on a repeated-prompt trace the
//! steady-state hit rate clears the serving bar with a real NFE cut.

use std::sync::Arc;

use sada::pipeline::{Accelerator, CacheOutcome, GenRequest, Pipeline};
use sada::plancache::{
    schedule_fingerprint, Directive, PlanStore, RecordedPlan, SpeculativeAccel,
};
use sada::runtime::mock::GmBackend;
use sada::runtime::ModelBackend;
use sada::sada::Sada;
use sada::solvers::{Schedule, SolverKind};
use sada::testutil::{check, Pair, UsizeIn};
use sada::workload::{PromptBank, TraceGen};
use sada::Tensor;

fn dpmpp_fp() -> u64 {
    schedule_fingerprint(SolverKind::DpmPP.name(), &Schedule::default_ddpm())
}

fn spec_for(backend: &GmBackend, steps: usize, store: Arc<PlanStore>) -> SpeculativeAccel {
    SpeculativeAccel::new(
        Sada::with_default(backend.info(), steps),
        store,
        &backend.info().name,
        dpmpp_fp(),
    )
}

fn request(case: u64, steps: usize, guidance: f32) -> GenRequest {
    let mut rng = sada::rng::Rng::new(1000 + case);
    GenRequest {
        cond: Tensor::from_rng(&mut rng, &[1, 32]),
        seed: 31 * case + 7,
        guidance,
        steps,
        edge: None,
    }
}

#[test]
fn property_empty_cache_is_bit_identical_to_plain_sada() {
    // over random seeds, step counts and guidance scales: a SpeculativeAccel
    // over an empty store produces the same images and the same NFE as the
    // Sada it wraps (the cold path is pure passthrough + recording)
    let gen = Pair(UsizeIn(0, 400), Pair(UsizeIn(8, 40), UsizeIn(0, 12)));
    check(11, 8, &gen, |(case, (steps, gs_half))| {
        let guidance = *gs_half as f32 * 0.5;
        let backend = GmBackend::new(3 + (*case as u64 % 5));
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let req = request(*case as u64, *steps, guidance);
        let mut plain = Sada::with_default(backend.info(), *steps);
        let base = pipe
            .generate(&req, &mut plain)
            .map_err(|e| format!("plain sada failed: {e:#}"))?;
        let store = Arc::new(PlanStore::new(64));
        let mut spec = spec_for(&backend, *steps, store.clone());
        let res = pipe
            .generate(&req, &mut spec)
            .map_err(|e| format!("speculative failed: {e:#}"))?;
        if res.image.data() != base.image.data() {
            return Err(format!("images differ (steps={steps}, gs={guidance})"));
        }
        if res.stats.nfe != base.stats.nfe {
            return Err(format!("nfe {} != {}", res.stats.nfe, base.stats.nfe));
        }
        if res.stats.mode_trace() != base.stats.mode_trace() {
            return Err(format!(
                "traces differ: {} vs {}",
                res.stats.mode_trace(),
                base.stats.mode_trace()
            ));
        }
        match res.stats.outcome {
            CacheOutcome::Miss | CacheOutcome::Uncached => Ok(()),
            other => Err(format!("empty cache produced outcome {other:?}")),
        }
    });
}

#[test]
fn property_always_diverging_cache_is_bit_identical_to_plain_sada() {
    // a cache whose entries always fail early-sign verification must fall
    // back to plain SADA before replaying a single directive
    let gen = Pair(UsizeIn(0, 400), UsizeIn(12, 40));
    check(13, 6, &gen, |(case, steps)| {
        let backend = GmBackend::new(4 + (*case as u64 % 5));
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let req = request(*case as u64, *steps, 2.0);
        // discover the honest key + early signs on a scratch store
        let scratch = Arc::new(PlanStore::new(64));
        let mut probe = spec_for(&backend, *steps, scratch.clone());
        pipe.generate(&req, &mut probe)
            .map_err(|e| format!("probe failed: {e:#}"))?;
        let key = match probe.request_key() {
            Some(k) => k.clone(),
            // run too short to ever consult the cache: nothing to poison
            None => return Ok(()),
        };
        let honest = match scratch.get(&key) {
            Some(p) => p,
            None => return Ok(()), // no insertion (no early dots): inert
        };
        let store = Arc::new(PlanStore::new(64));
        store.insert(
            key,
            RecordedPlan {
                n_steps: honest.n_steps,
                directives: vec![Directive::SkipLagrange; honest.n_steps],
                verdicts: vec![None; honest.n_steps],
                early_signs: honest.early_signs.iter().map(|(i, s)| (*i, !*s)).collect(),
                nfe: 0,
            },
        );
        let mut plain = Sada::with_default(backend.info(), *steps);
        let base = pipe
            .generate(&req, &mut plain)
            .map_err(|e| format!("plain sada failed: {e:#}"))?;
        let mut spec = spec_for(&backend, *steps, store.clone());
        let res = pipe
            .generate(&req, &mut spec)
            .map_err(|e| format!("speculative failed: {e:#}"))?;
        if res.image.data() != base.image.data() {
            return Err("diverging cache changed the image".into());
        }
        if res.stats.nfe != base.stats.nfe {
            return Err(format!("nfe {} != {}", res.stats.nfe, base.stats.nfe));
        }
        if honest.early_signs.is_empty() {
            return Ok(()); // nothing could mismatch: lookup was a hit/miss
        }
        match res.stats.outcome {
            CacheOutcome::Diverged { .. } => Ok(()),
            other => Err(format!("expected divergence, got {other:?}")),
        }
    });
}

#[test]
fn steady_state_hit_rate_clears_the_serving_bar_with_an_nfe_cut() {
    // the acceptance workload in miniature: a repeated-prompt trace must
    // reach >= 80% steady-state hit rate and a measurably lower mean NFE
    // than cold-start SADA
    let backend = GmBackend::new(5);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 40;
    let hot = 4usize;
    let bank = PromptBank::synthetic(hot, 32, 21);
    let trace = TraceGen::repeated(50.0, hot).generate(36, 7);
    let req_for = |idx: usize| GenRequest {
        cond: bank.get(idx).clone(),
        seed: bank.seed_for(idx),
        guidance: 3.0,
        steps,
        edge: None,
    };

    let mut cold = Sada::with_default(backend.info(), steps);
    let mut cold_nfe = 0usize;
    for arr in &trace {
        cold_nfe += pipe.generate(&req_for(arr.prompt_idx), &mut cold).unwrap().stats.nfe;
    }

    let store = Arc::new(PlanStore::new(64));
    let mut spec = spec_for(&backend, steps, store.clone());
    let mut seen = std::collections::HashSet::new();
    let (mut hits, mut repeats) = (0usize, 0usize);
    let mut warm_nfe = 0usize;
    for arr in &trace {
        let res = pipe.generate(&req_for(arr.prompt_idx), &mut spec).unwrap();
        if !seen.insert(arr.prompt_idx) {
            repeats += 1;
        }
        if res.stats.outcome == CacheOutcome::Hit {
            hits += 1;
        }
        warm_nfe += res.stats.nfe;
    }
    assert!(repeats > 20, "trace too short to measure steady state");
    let steady = hits as f64 / repeats as f64;
    assert!(
        steady >= 0.8,
        "steady-state hit rate {steady:.2} below the 0.8 bar \
         ({hits} hits / {repeats} repeats; store stats {:?})",
        store.stats()
    );
    assert!(
        warm_nfe < cold_nfe,
        "warm-start replay must cut NFE: warm={warm_nfe} cold={cold_nfe}"
    );
}

#[test]
fn replaying_lanes_co_schedule_into_full_buckets() {
    // two lanes replaying the same verified plan agree on every fresh step:
    // the lane engine gathers them into one full_b2 launch per fresh step
    let backend = GmBackend::with_batch_buckets(5, &[2]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 50;
    let store = Arc::new(PlanStore::new(64));
    let proto = spec_for(&backend, steps, store.clone());
    let proto: &dyn Accelerator = &proto;
    let req = request(7, steps, 2.0);
    let reqs = vec![req.clone(), req];
    let cold = pipe.generate_lanes(&reqs, proto).unwrap();
    for r in &cold {
        assert_eq!(r.stats.outcome, CacheOutcome::Miss);
    }
    backend.reset_nfe();
    let warm = pipe.generate_lanes(&reqs, proto).unwrap();
    for r in &warm {
        assert_eq!(r.stats.outcome, CacheOutcome::Hit);
    }
    // co-scheduled replay: one bucketed launch per fresh step, not two
    assert_eq!(
        backend.nfe(),
        warm[0].stats.nfe,
        "fresh steps must share full_b2 launches (trace={})",
        warm[0].stats.mode_trace()
    );
    assert!(
        warm[0].stats.nfe < cold[0].stats.nfe,
        "replay must skip the detection pattern: warm={} cold={}",
        warm[0].stats.nfe,
        cold[0].stats.nfe
    );
}

#[test]
fn mixed_cached_and_plain_lanes_do_not_interfere() {
    // a replaying lane next to a NoAccel lane: the NoAccel lane stays
    // bit-identical to its sequential run, replay or not
    use sada::pipeline::lanes::FnFactory;
    use sada::pipeline::NoAccel;
    let backend = GmBackend::with_batch_buckets(5, &[2]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 30;
    let store = Arc::new(PlanStore::new(64));
    let cached = request(9, steps, 2.0);
    let plain = request(10, steps, 4.0);
    // warm the cache for the cached lane's request
    {
        let mut spec = spec_for(&backend, steps, store.clone());
        pipe.generate(&cached, &mut spec).unwrap();
    }
    let info = backend.info().clone();
    let store_f = store.clone();
    let factory = FnFactory(move |lane: usize| -> Box<dyn Accelerator> {
        if lane == 0 {
            Box::new(SpeculativeAccel::new(
                Sada::with_default(&info, steps),
                store_f.clone(),
                &info.name,
                dpmpp_fp(),
            ))
        } else {
            Box::new(NoAccel)
        }
    });
    let lanes = pipe.generate_lanes(&[cached, plain.clone()], &factory).unwrap();
    assert_eq!(lanes[0].stats.outcome, CacheOutcome::Hit);
    assert_eq!(lanes[1].stats.outcome, CacheOutcome::Uncached);
    let solo = pipe.generate(&plain, &mut NoAccel).unwrap();
    assert_eq!(lanes[1].image.data(), solo.image.data());
    assert_eq!(lanes[1].stats.nfe, solo.stats.nfe);
}
