//! Skip-plan cache invariants (no artifacts needed: analytic GM backend).
//!
//! The load-bearing contract: speculative warm-start replay may only ever
//! *save* work, never change what a cacheless run would have produced —
//! an empty cache and an always-diverging cache are both bit-identical to
//! plain SADA (same images, same NFE), and on a repeated-prompt trace the
//! steady-state hit rate clears the serving bar with a real NFE cut.

use std::sync::Arc;

use sada::pipeline::{Accelerator, CacheOutcome, GenRequest, KeepMask, Pipeline, StepMode};
use sada::plancache::{
    schedule_fingerprint, Directive, PlanStore, RecordedPlan, SpeculativeAccel,
};
use sada::runtime::mock::GmBackend;
use sada::runtime::ModelBackend;
use sada::sada::Sada;
use sada::solvers::{Schedule, SolverKind};
use sada::testutil::{check, Pair, UsizeIn};
use sada::workload::{PromptBank, TraceGen};
use sada::Tensor;

fn dpmpp_fp() -> u64 {
    schedule_fingerprint(SolverKind::DpmPP.name(), &Schedule::default_ddpm())
}

fn spec_for(backend: &GmBackend, steps: usize, store: Arc<PlanStore>) -> SpeculativeAccel {
    SpeculativeAccel::new(
        Sada::with_default(backend.info(), steps),
        store,
        &backend.info().name,
        dpmpp_fp(),
    )
}

fn request(case: u64, steps: usize, guidance: f32) -> GenRequest {
    let mut rng = sada::rng::Rng::new(1000 + case);
    GenRequest {
        cond: Tensor::from_rng(&mut rng, &[1, 32]),
        seed: 31 * case + 7,
        guidance,
        steps,
        edge: None,
    }
}

#[test]
fn property_empty_cache_is_bit_identical_to_plain_sada() {
    // over random seeds, step counts and guidance scales: a SpeculativeAccel
    // over an empty store produces the same images and the same NFE as the
    // Sada it wraps (the cold path is pure passthrough + recording)
    let gen = Pair(UsizeIn(0, 400), Pair(UsizeIn(8, 40), UsizeIn(0, 12)));
    check(11, 8, &gen, |(case, (steps, gs_half))| {
        let guidance = *gs_half as f32 * 0.5;
        let backend = GmBackend::new(3 + (*case as u64 % 5));
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let req = request(*case as u64, *steps, guidance);
        let mut plain = Sada::with_default(backend.info(), *steps);
        let base = pipe
            .generate(&req, &mut plain)
            .map_err(|e| format!("plain sada failed: {e:#}"))?;
        let store = Arc::new(PlanStore::new(64));
        let mut spec = spec_for(&backend, *steps, store.clone());
        let res = pipe
            .generate(&req, &mut spec)
            .map_err(|e| format!("speculative failed: {e:#}"))?;
        if res.image.data() != base.image.data() {
            return Err(format!("images differ (steps={steps}, gs={guidance})"));
        }
        if res.stats.nfe != base.stats.nfe {
            return Err(format!("nfe {} != {}", res.stats.nfe, base.stats.nfe));
        }
        if res.stats.mode_trace() != base.stats.mode_trace() {
            return Err(format!(
                "traces differ: {} vs {}",
                res.stats.mode_trace(),
                base.stats.mode_trace()
            ));
        }
        match res.stats.outcome {
            CacheOutcome::Miss | CacheOutcome::Uncached => Ok(()),
            other => Err(format!("empty cache produced outcome {other:?}")),
        }
    });
}

#[test]
fn property_always_diverging_cache_is_bit_identical_to_plain_sada() {
    // a cache whose entries always fail early-sign verification must fall
    // back to plain SADA before replaying a single directive
    let gen = Pair(UsizeIn(0, 400), UsizeIn(12, 40));
    check(13, 6, &gen, |(case, steps)| {
        let backend = GmBackend::new(4 + (*case as u64 % 5));
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let req = request(*case as u64, *steps, 2.0);
        // discover the honest key + early signs on a scratch store
        let scratch = Arc::new(PlanStore::new(64));
        let mut probe = spec_for(&backend, *steps, scratch.clone());
        pipe.generate(&req, &mut probe)
            .map_err(|e| format!("probe failed: {e:#}"))?;
        let key = match probe.request_key() {
            Some(k) => k.clone(),
            // run too short to ever consult the cache: nothing to poison
            None => return Ok(()),
        };
        let honest = match scratch.get(&key) {
            Some(p) => p,
            None => return Ok(()), // no insertion (no early dots): inert
        };
        let store = Arc::new(PlanStore::new(64));
        store.insert(
            key,
            RecordedPlan {
                n_steps: honest.n_steps,
                directives: vec![Directive::SkipLagrange; honest.n_steps],
                masks: Vec::new(),
                verdicts: vec![None; honest.n_steps],
                early_signs: honest.early_signs.iter().map(|(i, s)| (*i, !*s)).collect(),
                nfe: 0,
            },
        );
        let mut plain = Sada::with_default(backend.info(), *steps);
        let base = pipe
            .generate(&req, &mut plain)
            .map_err(|e| format!("plain sada failed: {e:#}"))?;
        let mut spec = spec_for(&backend, *steps, store.clone());
        let res = pipe
            .generate(&req, &mut spec)
            .map_err(|e| format!("speculative failed: {e:#}"))?;
        if res.image.data() != base.image.data() {
            return Err("diverging cache changed the image".into());
        }
        if res.stats.nfe != base.stats.nfe {
            return Err(format!("nfe {} != {}", res.stats.nfe, base.stats.nfe));
        }
        if honest.early_signs.is_empty() {
            return Ok(()); // nothing could mismatch: lookup was a hit/miss
        }
        match res.stats.outcome {
            CacheOutcome::Diverged { .. } => Ok(()),
            other => Err(format!("expected divergence, got {other:?}")),
        }
    });
}

#[test]
fn steady_state_hit_rate_clears_the_serving_bar_with_an_nfe_cut() {
    // the acceptance workload in miniature: a repeated-prompt trace must
    // reach >= 80% steady-state hit rate and a measurably lower mean NFE
    // than cold-start SADA
    let backend = GmBackend::new(5);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 40;
    let hot = 4usize;
    let bank = PromptBank::synthetic(hot, 32, 21);
    let trace = TraceGen::repeated(50.0, hot).generate(36, 7);
    let req_for = |idx: usize| GenRequest {
        cond: bank.get(idx).clone(),
        seed: bank.seed_for(idx),
        guidance: 3.0,
        steps,
        edge: None,
    };

    let mut cold = Sada::with_default(backend.info(), steps);
    let mut cold_nfe = 0usize;
    for arr in &trace {
        cold_nfe += pipe.generate(&req_for(arr.prompt_idx), &mut cold).unwrap().stats.nfe;
    }

    let store = Arc::new(PlanStore::new(64));
    let mut spec = spec_for(&backend, steps, store.clone());
    let mut seen = std::collections::HashSet::new();
    let (mut hits, mut repeats) = (0usize, 0usize);
    let mut warm_nfe = 0usize;
    for arr in &trace {
        let res = pipe.generate(&req_for(arr.prompt_idx), &mut spec).unwrap();
        if !seen.insert(arr.prompt_idx) {
            repeats += 1;
        }
        if res.stats.outcome == CacheOutcome::Hit {
            hits += 1;
        }
        warm_nfe += res.stats.nfe;
    }
    assert!(repeats > 20, "trace too short to measure steady state");
    let steady = hits as f64 / repeats as f64;
    assert!(
        steady >= 0.8,
        "steady-state hit rate {steady:.2} below the 0.8 bar \
         ({hits} hits / {repeats} repeats; store stats {:?})",
        store.stats()
    );
    assert!(
        warm_nfe < cold_nfe,
        "warm-start replay must cut NFE: warm={warm_nfe} cold={cold_nfe}"
    );
}

#[test]
fn replaying_lanes_co_schedule_into_full_buckets() {
    // two lanes replaying the same verified plan agree on every fresh step:
    // the lane engine gathers them into one full_b2 launch per fresh step
    let backend = GmBackend::with_batch_buckets(5, &[2]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 50;
    let store = Arc::new(PlanStore::new(64));
    let proto = spec_for(&backend, steps, store.clone());
    let proto: &dyn Accelerator = &proto;
    let req = request(7, steps, 2.0);
    let reqs = vec![req.clone(), req];
    let cold = pipe.generate_lanes(&reqs, proto).unwrap();
    for r in &cold {
        assert_eq!(r.stats.outcome, CacheOutcome::Miss);
    }
    backend.reset_nfe();
    let warm = pipe.generate_lanes(&reqs, proto).unwrap();
    for r in &warm {
        assert_eq!(r.stats.outcome, CacheOutcome::Hit);
    }
    // co-scheduled replay: plain Full steps share one full_b2 launch for
    // both lanes. Token-pruned/shallow steps — and the CacheWarm capture
    // singles that feed them — legitimately cost one model call per lane
    // (aux features are not sliceable from a bucketed launch), so the
    // exact one-launch-per-fresh-step accounting only applies to plans
    // without token directives; with them, co-scheduling must still beat
    // fully-single execution
    let mut probe = spec_for(&backend, steps, store.clone());
    probe.begin_run(&reqs[0]);
    let stored = store.get(probe.request_key().unwrap()).expect("plan recorded");
    let has_token_directives = stored
        .directives
        .iter()
        .any(|d| matches!(d, Directive::Prune { .. } | Directive::Shallow));
    if has_token_directives {
        assert!(
            backend.nfe() < warm[0].stats.nfe + warm[1].stats.nfe,
            "co-scheduling must share at least one bucket launch (trace={})",
            warm[0].stats.mode_trace()
        );
    } else {
        assert_eq!(
            backend.nfe(),
            warm[0].stats.nfe,
            "fresh steps must share full_b2 launches (trace={})",
            warm[0].stats.mode_trace()
        );
    }
    assert!(
        warm[0].stats.nfe < cold[0].stats.nfe,
        "replay must skip the detection pattern: warm={} cold={}",
        warm[0].stats.nfe,
        cold[0].stats.nfe
    );
}

/// Graft [`Directive::Prune`] (keep-all mask => token coverage always
/// verifies) onto every interior Full directive of `plan`, far enough past
/// the lookup region that the replay is already live. Returns the grafted
/// plan and the number of grafted steps.
fn graft_token_directives(plan: &RecordedPlan, steps: usize) -> (RecordedPlan, usize) {
    let mask = Arc::new(KeepMask { variant: "prune75".into(), keep_idx: (0..16).collect() });
    let mut grafted = plan.clone();
    grafted.masks = vec![mask];
    let mut n = 0;
    for d in grafted.directives.iter_mut().take(steps.saturating_sub(2)).skip(8) {
        if *d == Directive::Full {
            *d = Directive::Prune { mask: 0 };
            n += 1;
        }
    }
    grafted.nfe = grafted.directives.iter().filter(|d| d.is_fresh()).count();
    (grafted, n)
}

#[test]
fn recorded_token_directives_replay_natively_on_hits() {
    // a plan with token directives over a zero-variant-noise backend (so
    // prune == full bitwise): the warm run must Hit, execute every token
    // directive as StepMode::Prune with zero degraded-to-Full prunes, and
    // produce exactly the image the unmodified plan replays to
    let mut backend = GmBackend::new(5);
    backend.variant_noise = 0.0;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 50;
    let req = request(7, steps, 2.0);
    let store = Arc::new(PlanStore::new(64));
    let mut spec = spec_for(&backend, steps, store.clone());
    pipe.generate(&req, &mut spec).unwrap();
    let key = spec.request_key().unwrap().clone();
    let honest = store.get(&key).unwrap();
    // reference: replay of the unmodified plan
    let reference = pipe.generate(&req, &mut spec).unwrap();
    assert_eq!(reference.stats.outcome, CacheOutcome::Hit);
    let (grafted, n_grafted) = graft_token_directives(&honest, steps);
    assert!(n_grafted > 0, "graft found no interior Full steps");
    store.insert(key, grafted);
    let warm = pipe.generate(&req, &mut spec).unwrap();
    assert_eq!(
        warm.stats.outcome,
        CacheOutcome::Hit,
        "token replay must stay verified: trace={}",
        warm.stats.mode_trace()
    );
    assert_eq!(
        warm.stats.count(StepMode::Prune),
        n_grafted,
        "every token directive must execute as Prune, not Full: trace={}",
        warm.stats.mode_trace()
    );
    assert_eq!(warm.stats.degraded.prune, 0, "zero degraded prunes after warm-up");
    // prune == full bitwise at zero variant noise: the token replay is
    // bit-identical to the unmodified plan's replay
    assert_eq!(warm.image.data(), reference.image.data());
    assert_eq!(warm.stats.nfe, reference.stats.nfe);
}

#[test]
fn cache_warm_lanes_replay_token_directives_without_degradation() {
    // the lane-engine (bucketed) version: replaying lanes execute their
    // Full steps through shared full_b2 launches, yet every token
    // directive still replays as StepMode::Prune — the CacheWarm capture
    // single re-validates the lane's caches before the first prune and
    // each prune refreshes its own
    let mut backend = GmBackend::with_batch_buckets(5, &[2]);
    backend.variant_noise = 0.0;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 50;
    let store = Arc::new(PlanStore::new(64));
    let req = request(7, steps, 2.0);
    {
        let mut spec = spec_for(&backend, steps, store.clone());
        pipe.generate(&req, &mut spec).unwrap();
        let key = spec.request_key().unwrap().clone();
        let honest = store.get(&key).unwrap();
        let (grafted, n) = graft_token_directives(&honest, steps);
        assert!(n > 0, "graft found no interior Full steps");
        store.insert(key, grafted);
    }
    let proto = spec_for(&backend, steps, store.clone());
    let proto: &dyn Accelerator = &proto;
    let warm = pipe.generate_lanes(&[req.clone(), req], proto).unwrap();
    for (k, lane) in warm.iter().enumerate() {
        assert_eq!(
            lane.stats.outcome,
            CacheOutcome::Hit,
            "lane {k} must replay: trace={}",
            lane.stats.mode_trace()
        );
        assert!(
            lane.stats.count(StepMode::Prune) > 0,
            "lane {k} lost its token directives: trace={}",
            lane.stats.mode_trace()
        );
        assert_eq!(
            lane.stats.degraded.prune,
            0,
            "lane {k}: a replayed prune degraded to Full (caches went stale): trace={}",
            lane.stats.mode_trace()
        );
    }
}

#[test]
fn mixed_cached_and_plain_lanes_do_not_interfere() {
    // a replaying lane next to a NoAccel lane: the NoAccel lane stays
    // bit-identical to its sequential run, replay or not
    use sada::pipeline::lanes::FnFactory;
    use sada::pipeline::NoAccel;
    let backend = GmBackend::with_batch_buckets(5, &[2]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 30;
    let store = Arc::new(PlanStore::new(64));
    let cached = request(9, steps, 2.0);
    let plain = request(10, steps, 4.0);
    // warm the cache for the cached lane's request
    {
        let mut spec = spec_for(&backend, steps, store.clone());
        pipe.generate(&cached, &mut spec).unwrap();
    }
    let info = backend.info().clone();
    let store_f = store.clone();
    let factory = FnFactory(move |lane: usize| -> Box<dyn Accelerator> {
        if lane == 0 {
            Box::new(SpeculativeAccel::new(
                Sada::with_default(&info, steps),
                store_f.clone(),
                &info.name,
                dpmpp_fp(),
            ))
        } else {
            Box::new(NoAccel)
        }
    });
    let lanes = pipe.generate_lanes(&[cached, plain.clone()], &factory).unwrap();
    assert_eq!(lanes[0].stats.outcome, CacheOutcome::Hit);
    assert_eq!(lanes[1].stats.outcome, CacheOutcome::Uncached);
    let solo = pipe.generate(&plain, &mut NoAccel).unwrap();
    assert_eq!(lanes[1].image.data(), solo.image.data());
    assert_eq!(lanes[1].stats.nfe, solo.stats.nfe);
}
