//! Arena/view execution is bit-identical to sequential generation.
//!
//! The zero-copy hot path (reused step buffers, `_into` solver kernels,
//! arena-pooled bucket gathers, `run_into` backends) must produce exactly
//! the bytes the allocating seed path produced. The referee is
//! per-request [`Pipeline::generate`] — itself pinned by the golden
//! suites — compared against the lane engine over random seeds, step
//! counts, guidance values and mixed-lane batches, for every accelerator
//! (including `sada-cache` lanes over an empty store, which behave as
//! recording passthroughs).

use std::sync::Arc;

use sada::testutil::alloc::{thread_allocs, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

use sada::baselines::{AdaptiveDiffusion, DeepCache, TeaCache};
use sada::pipeline::lanes::FnFactory;
use sada::pipeline::{Accelerator, GenRequest, KeepMask, NoAccel, Pipeline};
use sada::plancache::{
    schedule_fingerprint, Directive, PlanStore, RecordedPlan, SpeculativeAccel,
};
use sada::runtime::mock::GmBackend;
use sada::runtime::ModelBackend;
use sada::sada::Sada;
use sada::solvers::{Schedule, SolverKind};
use sada::tensor::Tensor;

/// Every accelerator, bit-identical on every backend flavor: unbucketed
/// (all singles), full-bucket, and degraded-variant-bucket backends.
/// Bucketed full launches capture aux batch-major and scatter row k into
/// lane k's retained slots (exactly what its solo single would have
/// captured), and Shallow/Prune lanes batch through compiled
/// `shallow_b{n}` / `prune{k}_b{n}` buckets with per-lane-sliceable aux
/// gathers — so aux-dependent accelerators (DeepCache's shallow path,
/// SADA's token pruning, cache-warm replays) no longer trade their
/// degraded-variant discount for gather throughput.
const ACCELS: &[&str] = &["baseline", "sada", "sada-cache", "deepcache", "adaptive", "teacache"];

/// Backend flavors every bit-identity property runs against.
const BACKENDS: &[&str] = &["plain", "full_buckets", "variant_buckets"];

fn backend_for(kind: &str, seed: u64) -> GmBackend {
    match kind {
        "full_buckets" => GmBackend::with_batch_buckets(seed, &[2, 4]),
        "variant_buckets" => GmBackend::with_variant_buckets(seed, &[2, 4]),
        _ => GmBackend::new(seed),
    }
}

fn accel_for(name: &str, backend: &GmBackend, steps: usize) -> Box<dyn Accelerator> {
    match name {
        "sada" => Box::new(Sada::with_default(backend.info(), steps)),
        "sada-cache" => {
            // fresh empty store per construction: lanes all miss (plans are
            // only inserted at run completion), matching a sequential run
            // against an empty store bit for bit
            let fp = schedule_fingerprint(SolverKind::DpmPP.name(), &Schedule::default_ddpm());
            Box::new(SpeculativeAccel::new(
                Sada::with_default(backend.info(), steps),
                Arc::new(PlanStore::new(64)),
                &backend.info().name,
                fp,
            ))
        }
        "deepcache" => Box::new(DeepCache::new(3)),
        "adaptive" => Box::new(AdaptiveDiffusion::default()),
        "teacache" => Box::new(TeaCache::default()),
        _ => Box::new(NoAccel),
    }
}

fn reqs_for(n: usize, steps: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = sada::rng::Rng::new(seed);
    (0..n)
        .map(|k| GenRequest {
            cond: Tensor::from_rng(&mut rng, &[1, 32]),
            seed: rng.below(100_000),
            guidance: [0.0f32, 2.0, 3.5, 5.0][k % 4],
            steps,
            edge: None,
        })
        .collect()
}

/// Lane results must match per-request sequential generation bitwise:
/// same image bytes, same NFE, same mode trace.
fn assert_lanes_match_sequential(
    backend: &GmBackend,
    accel: &str,
    reqs: &[GenRequest],
    ctx: &str,
) {
    let pipe = Pipeline::new(backend, SolverKind::DpmPP);
    let steps = reqs[0].steps;
    let proto = accel_for(accel, backend, steps);
    let lanes = pipe
        .generate_lanes(reqs, proto.as_ref())
        .unwrap_or_else(|e| panic!("{ctx}: lane engine failed: {e:#}"));
    for (k, (lane, req)) in lanes.iter().zip(reqs).enumerate() {
        let mut solo = accel_for(accel, backend, steps);
        let seq = pipe
            .generate(req, solo.as_mut())
            .unwrap_or_else(|e| panic!("{ctx}: sequential failed: {e:#}"));
        assert_eq!(
            lane.image.data(),
            seq.image.data(),
            "{ctx}: lane {k} ({accel}) not bit-identical to sequential"
        );
        assert_eq!(lane.stats.nfe, seq.stats.nfe, "{ctx}: lane {k} ({accel}) NFE");
        assert_eq!(
            lane.stats.mode_trace(),
            seq.stats.mode_trace(),
            "{ctx}: lane {k} ({accel}) mode trace"
        );
    }
}

#[test]
fn property_every_accel_lane_batch_is_bit_identical_to_sequential() {
    for (round, &(seed, steps, batch)) in [
        (11u64, 9usize, 1usize),
        (23, 21, 3),
        (37, 34, 5),
        (53, 13, 4),
    ]
    .iter()
    .enumerate()
    {
        for kind in BACKENDS {
            let backend = backend_for(kind, seed);
            let reqs = reqs_for(batch, steps, seed * 17 + round as u64);
            for accel in ACCELS {
                let ctx = format!(
                    "round {round} (seed {seed}, steps {steps}, b {batch}, backend {kind})"
                );
                assert_lanes_match_sequential(&backend, accel, &reqs, &ctx);
            }
        }
    }
}

#[test]
fn mixed_accelerator_lanes_stay_bit_identical() {
    // heterogeneous batch: every lane runs a different accelerator. No
    // compiled buckets, so every execution is a single and even the
    // aux-dependent accelerators must match their solo runs exactly.
    let backend = GmBackend::new(7);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 30;
    let mut reqs = reqs_for(4, steps, 99);
    // one guidance group (exercises the grouped scheduling bookkeeping)
    for r in reqs.iter_mut() {
        r.guidance = 3.0;
    }
    let kinds = ["baseline", "sada", "deepcache", "sada-cache"];
    let b2 = &backend;
    let factory = FnFactory(move |lane: usize| accel_for(kinds[lane], b2, steps));
    let lanes = pipe.generate_lanes(&reqs, &factory).unwrap();
    for (k, (lane, req)) in lanes.iter().zip(&reqs).enumerate() {
        let mut solo = accel_for(kinds[k], &backend, steps);
        let seq = pipe.generate(req, solo.as_mut()).unwrap();
        assert_eq!(
            lane.image.data(),
            seq.image.data(),
            "mixed lane {k} ({}) not bit-identical",
            kinds[k]
        );
        assert_eq!(lane.stats.mode_trace(), seq.stats.mode_trace(), "mixed lane {k}");
    }
}

#[test]
fn always_diverging_prune_heavy_plans_fall_back_bit_identically() {
    // a poisoned store whose entries carry token-pruned + Lagrange
    // directives but contradictory early signs: every lane diverges at
    // lookup, and the fallback must be bit-identical to plain SADA — a
    // wrong prune-heavy plan can never corrupt output, it only costs the
    // replay. Unbucketed backend: plain-SADA lanes are bit-identical to
    // sequential there, so the referee is exact.
    let backend = GmBackend::new(21);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let steps = 30;
    let reqs = reqs_for(3, steps, 71);
    let fp = schedule_fingerprint(SolverKind::DpmPP.name(), &Schedule::default_ddpm());
    let poisoned = Arc::new(PlanStore::new(64));
    let mask = Arc::new(KeepMask { variant: "prune50".into(), keep_idx: (0..8).collect() });
    for req in &reqs {
        // discover the honest key + early signs on a scratch store
        let scratch = Arc::new(PlanStore::new(64));
        let mut probe = SpeculativeAccel::new(
            Sada::with_default(backend.info(), steps),
            scratch.clone(),
            &backend.info().name,
            fp,
        );
        pipe.generate(req, &mut probe).unwrap();
        let key = probe.request_key().unwrap().clone();
        let honest = match scratch.get(&key) {
            Some(p) => p,
            None => continue, // run never consulted the cache: inert
        };
        let mut directives = vec![Directive::Full; steps];
        for (i, d) in directives.iter_mut().enumerate().take(steps - 2).skip(6) {
            *d = if i % 2 == 0 {
                Directive::Prune { mask: 0 }
            } else {
                Directive::SkipLagrange
            };
        }
        poisoned.insert(
            key,
            RecordedPlan {
                n_steps: steps,
                directives,
                masks: vec![mask.clone()],
                verdicts: vec![None; steps],
                early_signs: honest.early_signs.iter().map(|(i, s)| (*i, !*s)).collect(),
                nfe: 0,
            },
        );
    }
    let store_f = poisoned.clone();
    let info = backend.info().clone();
    let factory = FnFactory(move |_lane: usize| -> Box<dyn Accelerator> {
        Box::new(SpeculativeAccel::new(
            Sada::with_default(&info, steps),
            store_f.clone(),
            &info.name,
            fp,
        ))
    });
    let lanes = pipe.generate_lanes(&reqs, &factory).unwrap();
    for (k, (lane, req)) in lanes.iter().zip(&reqs).enumerate() {
        assert_ne!(
            lane.stats.outcome,
            sada::pipeline::CacheOutcome::Hit,
            "lane {k} must not replay contradicted early signs"
        );
        let mut plain = Sada::with_default(backend.info(), steps);
        let solo = pipe.generate(req, &mut plain).unwrap();
        assert_eq!(
            lane.image.data(),
            solo.image.data(),
            "lane {k}: a diverging prune-heavy cache changed the image"
        );
        assert_eq!(lane.stats.nfe, solo.stats.nfe, "lane {k} NFE");
        assert_eq!(lane.stats.mode_trace(), solo.stats.mode_trace(), "lane {k} trace");
    }
}

#[test]
fn midflight_admitted_lanes_are_bit_identical_to_solo_runs() {
    // Continuous engine: 5 requests stream through 2 slots, one admission
    // per freed slot, so lanes 1..4 join a *running* engine at staggered
    // steps (lane k starts while earlier lanes are mid-trajectory, in
    // slots carrying another request's leftover state). Admission timing
    // must be invisible in the output: every lane matches its sequential
    // solo run bit for bit — image bytes, NFE, and mode trace — for every
    // accelerator on every backend flavor, degraded-variant buckets
    // included.
    use sada::pipeline::{AdmittedLane, GenResult, LaneFeeder};
    use std::collections::VecDeque;

    struct StaggerFeeder<'a> {
        backend: &'a GmBackend,
        accel: &'a str,
        pending: VecDeque<GenRequest>,
        results: Vec<Option<GenResult>>,
        next_tag: u64,
    }
    impl LaneFeeder for StaggerFeeder<'_> {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some(req) = self.pending.pop_front() else { return Vec::new() };
            let steps = req.steps;
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel: accel_for(self.accel, self.backend, steps), tag }]
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            if let Some(slot) = self.results.get_mut(tag as usize) {
                *slot = Some(result);
            }
        }
    }

    for kind in BACKENDS {
        let backend = backend_for(kind, 31);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let steps = 18;
        let reqs = reqs_for(5, steps, 311);
        for accel in ACCELS {
            let ctx = format!("continuous {accel} (backend {kind})");
            let mut feeder = StaggerFeeder {
                backend: &backend,
                accel,
                pending: reqs.clone().into(),
                results: (0..reqs.len()).map(|_| None).collect(),
                next_tag: 0,
            };
            let stats = pipe.generate_continuous(2, &mut feeder).unwrap();
            assert_eq!(stats.admitted, reqs.len(), "{ctx}: all requests admitted");
            assert_eq!(stats.completed, reqs.len(), "{ctx}: all lanes completed");
            assert!(
                stats.steps > steps,
                "{ctx}: admissions must stagger (engine ran only {} steps)",
                stats.steps
            );
            for (k, req) in reqs.iter().enumerate() {
                let res = feeder.results[k]
                    .as_ref()
                    .unwrap_or_else(|| panic!("{ctx}: lane {k} produced no result"));
                let mut solo = accel_for(accel, &backend, steps);
                let seq = pipe.generate(req, solo.as_mut()).unwrap();
                assert_eq!(
                    res.image.data(),
                    seq.image.data(),
                    "{ctx}: lane {k} admitted mid-flight not bit-identical to solo"
                );
                assert_eq!(res.stats.nfe, seq.stats.nfe, "{ctx}: lane {k} NFE");
                assert_eq!(
                    res.stats.mode_trace(),
                    seq.stats.mode_trace(),
                    "{ctx}: lane {k} mode trace"
                );
            }
        }
    }
}

#[test]
fn batched_prune_and_shallow_buckets_are_bit_identical_to_singles() {
    // The degraded-variant bucket path end-to-end: mixed lane sets where
    // Full, Prune (one shared keep mask) and Shallow groups coexist in the
    // same engine step, over compiled `prune50_b{n}` / `shallow_b{n}` /
    // `full_b{n}` buckets. Every lane must match its solo sequential run
    // bit for bit — same image bytes, same mode trace, no structural
    // degradations — while the backend's launch counter proves the
    // gathering actually happened (launches < fresh steps).
    use sada::pipeline::{StepCtx, StepObs, StepPlan};

    struct ScriptedPrune {
        mask: Arc<KeepMask>,
    }
    impl Accelerator for ScriptedPrune {
        fn name(&self) -> String {
            "scripted-prune".into()
        }
        fn plan(&mut self, ctx: &StepCtx) -> StepPlan {
            if ctx.have_caches && ctx.i % 2 == 1 {
                StepPlan::Prune { mask: self.mask.clone() }
            } else {
                StepPlan::Full
            }
        }
        fn observe(&mut self, _o: &StepObs) {}
        fn wants_obs(&self) -> bool {
            false
        }
        fn reset(&mut self) {}
        fn clone_fresh(&self) -> Box<dyn Accelerator> {
            Box::new(ScriptedPrune { mask: self.mask.clone() })
        }
    }

    for (round, &(seed, steps, batch)) in
        [(3u64, 10usize, 2usize), (19, 17, 4), (41, 24, 6)].iter().enumerate()
    {
        let backend = GmBackend::with_variant_buckets(seed, &[2, 4]);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let mut reqs = reqs_for(batch, steps, 1000 + round as u64);
        for r in reqs.iter_mut() {
            r.guidance = 3.0; // one guidance group: maximal gathering
        }
        let mask = Arc::new(KeepMask { variant: "prune50".into(), keep_idx: (0..8).collect() });
        // mixed lane set: even lanes run the scripted prune schedule, odd
        // lanes a shallow-heavy DeepCache
        let m2 = mask.clone();
        let factory = FnFactory(move |lane: usize| -> Box<dyn Accelerator> {
            if lane % 2 == 0 {
                Box::new(ScriptedPrune { mask: m2.clone() })
            } else {
                Box::new(DeepCache::new(3))
            }
        });
        backend.reset_nfe();
        let lanes = pipe.generate_lanes(&reqs, &factory).unwrap();
        let launches = backend.nfe();
        let mut fresh_total = 0usize;
        for (k, (lane, req)) in lanes.iter().zip(&reqs).enumerate() {
            let mut solo: Box<dyn Accelerator> = if k % 2 == 0 {
                Box::new(ScriptedPrune { mask: mask.clone() })
            } else {
                Box::new(DeepCache::new(3))
            };
            let seq = pipe.generate(req, solo.as_mut()).unwrap();
            assert_eq!(
                lane.image.data(),
                seq.image.data(),
                "round {round}: lane {k} not bit-identical under degraded buckets"
            );
            assert_eq!(lane.stats.mode_trace(), seq.stats.mode_trace(), "round {round} lane {k}");
            assert_eq!(
                lane.stats.degraded.prune, 0,
                "round {round} lane {k}: batched prune must never degrade"
            );
            // every fresh step classified exactly once; solo runs classify
            // nothing (the lane engine owns the batched-vs-single split)
            assert_eq!(lane.stats.mix.total(), lane.stats.nfe, "round {round} lane {k} mix");
            assert_eq!(seq.stats.mix.total(), 0, "solo runs leave ExecMix at zero");
            assert!(
                lane.stats.mix.batched > 0,
                "round {round} lane {k}: never gathered (mix {:?})",
                lane.stats.mix
            );
            fresh_total += lane.stats.nfe;
        }
        assert!(
            launches < fresh_total,
            "round {round}: {fresh_total} fresh steps took {launches} launches — \
             degraded buckets saved nothing"
        );
    }
}

#[test]
fn preempted_and_resumed_lanes_are_bit_identical_to_solo_runs() {
    // Lane preemption end-to-end: a lane is checkpointed out of a running
    // engine at a scripted step (its slot handed to the next pending
    // request), parked for several engine steps, then restored into
    // whatever slot frees next — possibly a different slot carrying
    // another request's leftover state. The preempt/park/resume cycle
    // must be invisible in the output: every lane (the victim included)
    // matches its sequential solo run bit for bit — image bytes, NFE and
    // mode trace — for every accelerator on every backend flavor.
    use sada::pipeline::{AdmittedLane, GenResult, LaneCheckpoint, LaneFeeder, LaneStatus};
    use std::collections::VecDeque;

    struct PreemptFeeder<'a> {
        backend: &'a GmBackend,
        accel: &'a str,
        pending: VecDeque<GenRequest>,
        results: Vec<Option<GenResult>>,
        next_tag: u64,
        /// Lane tag to preempt, the engine-step count to preempt at, and
        /// how many engine steps the checkpoint stays parked.
        victim: u64,
        preempt_at: usize,
        park_for: usize,
        calls: usize,
        parked: Option<(LaneCheckpoint, usize)>,
        fired: bool,
    }
    impl LaneFeeder for PreemptFeeder<'_> {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some(req) = self.pending.pop_front() else { return Vec::new() };
            let steps = req.steps;
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel: accel_for(self.accel, self.backend, steps), tag }]
        }
        fn plan_preemptions(&mut self, lanes: &[LaneStatus]) -> Vec<(u64, f64)> {
            self.calls += 1;
            if !self.fired
                && self.calls >= self.preempt_at
                && lanes.iter().any(|l| l.tag == self.victim && l.step > 0)
            {
                self.fired = true;
                return vec![(self.victim, -1.0)];
            }
            Vec::new()
        }
        fn preempted(&mut self, ckpt: LaneCheckpoint) {
            assert_eq!(ckpt.tag(), self.victim, "only the nominated lane is preempted");
            assert!(ckpt.step() > 0 && ckpt.step() < ckpt.steps(), "mid-flight checkpoint");
            self.parked = Some((ckpt, self.calls));
        }
        fn resume(&mut self, free: usize) -> Vec<(LaneCheckpoint, f64)> {
            if free == 0 {
                return Vec::new();
            }
            if let Some((ckpt, at)) = self.parked.take() {
                if self.calls >= at + self.park_for || self.pending.is_empty() {
                    return vec![(ckpt, 5.0)];
                }
                self.parked = Some((ckpt, at));
            }
            Vec::new()
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            if let Some(slot) = self.results.get_mut(tag as usize) {
                *slot = Some(result);
            }
        }
    }

    for kind in BACKENDS {
        let backend = backend_for(kind, 47);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let steps = 18;
        let reqs = reqs_for(5, steps, 477);
        for (round, accel) in ACCELS.iter().enumerate() {
            let ctx = format!("preempt {accel} (backend {kind})");
            let mut feeder = PreemptFeeder {
                backend: &backend,
                accel,
                pending: reqs.clone().into(),
                results: (0..reqs.len()).map(|_| None).collect(),
                next_tag: 0,
                victim: (round as u64) % 2, // alternate which seed lane suffers
                preempt_at: 4 + round,      // vary the checkpointed step
                park_for: 5,
                calls: 0,
                parked: None,
                fired: false,
            };
            let stats = pipe.generate_continuous(2, &mut feeder).unwrap();
            assert!(feeder.fired, "{ctx}: the scripted preemption never fired");
            assert!(feeder.parked.is_none(), "{ctx}: parked checkpoint never resumed");
            assert_eq!(stats.preempted, 1, "{ctx}: ContinuousStats.preempted");
            assert_eq!(stats.resumed, 1, "{ctx}: ContinuousStats.resumed");
            assert_eq!(stats.admitted, reqs.len(), "{ctx}: all requests admitted");
            assert_eq!(stats.completed, reqs.len(), "{ctx}: all lanes completed");
            for (k, req) in reqs.iter().enumerate() {
                let res = feeder.results[k]
                    .as_ref()
                    .unwrap_or_else(|| panic!("{ctx}: lane {k} produced no result"));
                let mut solo = accel_for(accel, &backend, steps);
                let seq = pipe.generate(req, solo.as_mut()).unwrap();
                assert_eq!(
                    res.image.data(),
                    seq.image.data(),
                    "{ctx}: lane {k} not bit-identical to solo across preemption"
                );
                assert_eq!(res.stats.nfe, seq.stats.nfe, "{ctx}: lane {k} NFE");
                assert_eq!(
                    res.stats.mode_trace(),
                    seq.stats.mode_trace(),
                    "{ctx}: lane {k} mode trace"
                );
            }
        }
    }
}

#[test]
fn warm_arena_checkout_release_cycles_allocate_nothing() {
    // once a shape is pooled, checkout/release must be pure recycling —
    // the zero-alloc lane loop depends on this
    use sada::tensor::arena::{AuxSlot, TensorArena};
    let arena = TensorArena::new();
    let shapes: [&[usize]; 3] = [&[4, 16], &[1, 32], &[2, 8, 8]];
    for s in shapes {
        arena.release(arena.checkout(s)); // prime the pool for this shape
    }
    let mut aux = AuxSlot::new();
    aux.ensure(&arena, &[4, 16]);
    aux.retire(&arena); // pool the aux tensor too
    let before = thread_allocs();
    for _ in 0..64 {
        for s in shapes {
            let t = arena.checkout(s);
            arena.release(t);
        }
        let z = arena.checkout_zeroed(&[4, 16]);
        arena.release(z);
        aux.ensure(&arena, &[4, 16]);
        aux.retire(&arena);
    }
    assert_eq!(
        thread_allocs() - before,
        0,
        "warm checkout/release cycles must not touch the heap"
    );
}

#[test]
fn guidance_values_keep_their_own_sub_batches() {
    // two guidance groups over buckets (regression net for the grouped
    // gather bookkeeping rewrite): results still match per-request
    // sequential runs exactly. `adaptive` is aux-independent, so bucketed
    // execution must be bit-identical; its skip decisions also vary the
    // executed-batch composition step to step.
    let backend = GmBackend::with_batch_buckets(13, &[2]);
    let mut reqs = reqs_for(4, 25, 5);
    reqs[0].guidance = 1.0;
    reqs[1].guidance = 6.0;
    reqs[2].guidance = 1.0;
    reqs[3].guidance = 6.0;
    assert_lanes_match_sequential(&backend, "adaptive", &reqs, "two-group adaptive batch");
    // and the same shape without buckets for the aux-dependent planner
    let backend = GmBackend::new(13);
    assert_lanes_match_sequential(&backend, "sada", &reqs, "two-group sada batch");
}
