//! Trace-backed regression tests: the flight recorder's view of a
//! continuous run must reconstruct ground truth exactly, and the Chrome
//! trace export must be valid, strictly ordered Perfetto input.
//!
//! Everything runs on the `GmBackend` mock (no artifacts): the recorder
//! observes whatever the engine actually did, so the checks compare its
//! reconstruction against the engine's own `ContinuousStats` and each
//! lane's `RunStats`.

use std::collections::VecDeque;
use std::sync::Arc;

use sada::obs::chrome::chrome_trace;
use sada::obs::summary::{check_timeline, lane_timelines};
use sada::obs::{FlightRecorder, Sampling};
use sada::pipeline::{
    Accelerator, AdmittedLane, ContinuousStats, GenRequest, GenResult, LaneFeeder, NoAccel,
    Pipeline, RunStats, StepMode,
};
use sada::runtime::mock::GmBackend;
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::tensor::Tensor;
use sada::util::json::Json;

struct MixedFeeder {
    pending: VecDeque<(GenRequest, Box<dyn Accelerator>)>,
    next_tag: u64,
    done: Vec<(u64, RunStats)>,
}

impl LaneFeeder for MixedFeeder {
    fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
        let take = free.min(self.pending.len());
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            let Some((req, accel)) = self.pending.pop_front() else { break };
            out.push(AdmittedLane { req, accel, tag: self.next_tag });
            self.next_tag += 1;
        }
        out
    }

    fn complete(&mut self, tag: u64, res: GenResult) {
        self.done.push((tag, res.stats));
    }
}

/// Stream `n` mixed lanes (heterogeneous steps, SADA on even tags) through
/// a 3-slot continuous engine with the recorder attached.
fn run_recorded(
    sampling: Sampling,
    n: usize,
) -> (Arc<FlightRecorder>, ContinuousStats, Vec<(u64, RunStats)>) {
    let backend = GmBackend::with_batch_buckets(21, &[2, 4]);
    let mut pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let rec = FlightRecorder::with_capacity(sampling, 256, 1024);
    pipe.set_flight_recorder(rec.clone(), 0);
    let mut rng = sada::rng::Rng::new(4242);
    let mut pending: VecDeque<(GenRequest, Box<dyn Accelerator>)> = VecDeque::new();
    for i in 0..n {
        let steps = [6, 8, 10][i % 3];
        let req = GenRequest {
            cond: Tensor::from_rng(&mut rng, &[1, 32]),
            seed: rng.below(100_000),
            guidance: 3.0,
            steps,
            edge: None,
        };
        let accel: Box<dyn Accelerator> = if i % 2 == 0 {
            Box::new(Sada::with_default(backend.info(), steps))
        } else {
            Box::new(NoAccel)
        };
        pending.push_back((req, accel));
    }
    let mut feeder = MixedFeeder { pending, next_tag: 0, done: Vec::new() };
    let stats = pipe.generate_continuous(3, &mut feeder).unwrap();
    assert_eq!(stats.completed, n, "engine must drain the whole queue");
    (rec, stats, feeder.done)
}

#[test]
fn recorder_reconstructs_continuous_run_exactly() {
    let (rec, stats, done) = run_recorded(Sampling::Full, 7);
    let snap = rec.take_snapshot();
    assert_eq!(snap.total_dropped(), 0, "rings must hold the whole run");
    let tls = lane_timelines(&snap);
    assert_eq!(tls.len(), 7, "one timeline per lane");
    let mut lane_steps = 0usize;
    for tl in &tls {
        check_timeline(tl).unwrap();
        lane_steps += tl.steps.len();
        let (_, st) = done.iter().find(|(t, _)| *t == tl.tag).expect("RunStats for lane");
        let counts = tl.mode_counts();
        for (k, mode) in StepMode::ALL.iter().enumerate() {
            assert_eq!(
                counts[k],
                st.count(*mode),
                "lane {} mode {} count",
                tl.tag,
                mode.name()
            );
        }
        assert_eq!(tl.steps.len(), st.modes.len(), "lane {} step total", tl.tag);
        assert_eq!(tl.fresh_steps(), st.nfe, "lane {} nfe", tl.tag);
    }
    assert_eq!(lane_steps, stats.lane_steps, "recorded steps vs ContinuousStats");
    assert_eq!(tls.iter().filter(|t| t.admit_us.is_some()).count(), stats.admitted);
    assert_eq!(tls.iter().filter(|t| t.complete_us.is_some()).count(), stats.completed);
    // SADA lanes (even tags) surface criterion dots; NoAccel lanes never do
    assert!(
        tls.iter()
            .filter(|t| t.tag % 2 == 0)
            .any(|t| t.steps.iter().any(|s| s.dot.is_some())),
        "no criterion dot recorded on any SADA lane"
    );
    assert!(
        tls.iter()
            .filter(|t| t.tag % 2 == 1)
            .all(|t| t.steps.iter().all(|s| s.dot.is_none())),
        "passthrough lanes must not carry dots"
    );
}

#[test]
fn chrome_export_is_valid_ordered_perfetto_input() {
    let (rec, _, _) = run_recorded(Sampling::Full, 5);
    let doc = chrome_trace(&rec.take_snapshot());
    let text = doc.to_string();
    assert!(!text.contains("NaN"), "NaN is not valid JSON");
    let parsed = Json::parse(&text).expect("export must round-trip through the parser");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    // Perfetto-required fields on every event; strict per-track ordering
    let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut lane_tracks = 0usize;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        assert!(e.get("name").is_ok() && e.get("pid").is_ok() && e.get("tid").is_ok());
        if ph == "M" {
            if let Ok(args) = e.get("args") {
                if let Some(name) = args.opt("name").and_then(|n| n.as_str().ok()) {
                    if name.contains("lane") {
                        lane_tracks += 1;
                    }
                }
            }
            continue;
        }
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        if ph == "X" {
            assert!(e.get("dur").unwrap().as_f64().unwrap() > 0.0);
        }
        let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
        if let Some(prev) = last_ts.get(&tid) {
            assert!(ts > *prev, "track {tid}: ts {ts} not after {prev}");
        }
        last_ts.insert(tid, ts);
    }
    assert_eq!(lane_tracks, 5, "one named track per recorded lane");
}

#[test]
fn preempted_lane_timeline_shows_the_gap_and_reconciles_exactly() {
    // A preempting feeder rides the recorded engine: lane 0 is
    // checkpointed mid-flight, parked, and resumed into a freed slot.
    // The reconstruction must pair the Preempt with the Resume on lane
    // 0's timeline (same step index, resume strictly later), show NO
    // step events inside the gap, still validate via check_timeline, and
    // agree with ContinuousStats' preempted/resumed accounting. The
    // Chrome export stays strictly ordered with the new instant events.
    use sada::pipeline::{LaneCheckpoint, LaneStatus};

    struct PreemptingFeeder {
        pending: VecDeque<(GenRequest, Box<dyn Accelerator>)>,
        next_tag: u64,
        done: Vec<(u64, RunStats)>,
        calls: usize,
        parked: Option<(LaneCheckpoint, usize)>,
        fired: bool,
    }
    impl LaneFeeder for PreemptingFeeder {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some((req, accel)) = self.pending.pop_front() else { return Vec::new() };
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel, tag }]
        }
        fn plan_preemptions(&mut self, lanes: &[LaneStatus]) -> Vec<(u64, f64)> {
            self.calls += 1;
            if !self.fired && self.calls >= 3 && lanes.iter().any(|l| l.tag == 0 && l.step > 0)
            {
                self.fired = true;
                return vec![(0, -2.5)];
            }
            Vec::new()
        }
        fn preempted(&mut self, ckpt: LaneCheckpoint) {
            self.parked = Some((ckpt, self.calls));
        }
        fn resume(&mut self, free: usize) -> Vec<(LaneCheckpoint, f64)> {
            if free == 0 {
                return Vec::new();
            }
            if let Some((ckpt, at)) = self.parked.take() {
                if self.calls >= at + 3 || self.pending.is_empty() {
                    return vec![(ckpt, 7.5)];
                }
                self.parked = Some((ckpt, at));
            }
            Vec::new()
        }
        fn complete(&mut self, tag: u64, res: GenResult) {
            self.done.push((tag, res.stats));
        }
    }

    let backend = GmBackend::with_batch_buckets(33, &[2, 4]);
    let mut pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let rec = FlightRecorder::with_capacity(Sampling::Full, 256, 1024);
    pipe.set_flight_recorder(rec.clone(), 0);
    let mut rng = sada::rng::Rng::new(777);
    let mut pending: VecDeque<(GenRequest, Box<dyn Accelerator>)> = VecDeque::new();
    for _ in 0..4 {
        let req = GenRequest {
            cond: Tensor::from_rng(&mut rng, &[1, 32]),
            seed: rng.below(100_000),
            guidance: 3.0,
            steps: 10,
            edge: None,
        };
        pending.push_back((req, Box::new(NoAccel)));
    }
    let mut feeder =
        PreemptingFeeder { pending, next_tag: 0, done: Vec::new(), calls: 0, parked: None, fired: false };
    let stats = pipe.generate_continuous(2, &mut feeder).unwrap();
    assert!(feeder.fired, "the scripted preemption never fired");
    assert_eq!(stats.preempted, 1);
    assert_eq!(stats.resumed, 1);
    assert_eq!(stats.completed, 4);

    let snap = rec.take_snapshot();
    let tls = lane_timelines(&snap);
    assert_eq!(tls.len(), 4, "cross-slot resume must still yield one timeline per tag");
    let mut preempts = 0usize;
    let mut resumes = 0usize;
    for tl in &tls {
        check_timeline(tl).unwrap();
        preempts += tl.preempts.len();
        resumes += tl.resumes.len();
        let (_, st) = feeder.done.iter().find(|(t, _)| *t == tl.tag).unwrap();
        assert_eq!(tl.steps.len(), st.modes.len(), "lane {} ran every step", tl.tag);
        if tl.tag == 0 {
            assert_eq!(tl.preempts.len(), 1, "victim carries the Preempt event");
            assert_eq!(tl.resumes.len(), 1, "victim carries the Resume event");
            let (p_step, p_us, p_slack) = tl.preempts[0];
            let (r_step, r_us, r_slack) = tl.resumes[0];
            assert_eq!(p_step, r_step, "resume picks up at the checkpointed step");
            assert!(r_us > p_us, "the gap has positive width");
            assert_eq!(p_slack, -2.5, "queued-urgency slack rides the Preempt event");
            assert_eq!(r_slack, 7.5, "victim slack rides the Resume event");
            let gaps = tl.gaps();
            assert_eq!(gaps.len(), 1);
            assert!(
                !tl.steps.iter().any(|s| s.t_us > p_us && s.t_us < r_us),
                "no step may execute inside the preemption gap"
            );
        } else {
            assert!(tl.preempts.is_empty() && tl.resumes.is_empty());
        }
    }
    assert_eq!(preempts, stats.preempted, "timeline preempts vs ContinuousStats");
    assert_eq!(resumes, stats.resumed, "timeline resumes vs ContinuousStats");

    // the export stays valid, NaN/Inf-free, strictly ordered per track
    let doc = chrome_trace(&snap);
    let text = doc.to_string();
    assert!(!text.contains("NaN") && !text.contains("inf"), "non-finite JSON");
    let parsed = Json::parse(&text).expect("export must round-trip");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let mut names = Vec::new();
    let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue;
        }
        names.push(e.get("name").unwrap().as_str().unwrap().to_string());
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
        if let Some(prev) = last_ts.get(&tid) {
            assert!(ts > *prev, "track {tid}: ts {ts} not after {prev}");
        }
        last_ts.insert(tid, ts);
    }
    assert!(names.iter().any(|n| n == "preempt"), "export carries the preempt instant");
    assert!(names.iter().any(|n| n == "resume"), "export carries the resume instant");
}

#[test]
fn sampled_mode_records_only_matching_tags() {
    let (rec, stats, _) = run_recorded(Sampling::Sampled(2), 6);
    assert_eq!(stats.completed, 6, "sampling never changes execution");
    let tls = lane_timelines(&rec.take_snapshot());
    let tags: Vec<u64> = tls.iter().map(|t| t.tag).collect();
    assert_eq!(tags, vec![0, 2, 4], "1-in-2 sampling keeps even tags only");
    for tl in &tls {
        check_timeline(tl).unwrap();
    }
}

#[test]
fn off_sampling_records_nothing_and_costs_no_session() {
    let (rec, stats, done) = run_recorded(Sampling::Off, 4);
    assert_eq!(stats.completed, 4);
    assert_eq!(done.len(), 4);
    let snap = rec.take_snapshot();
    assert!(snap.sessions.is_empty(), "Off must open no sessions");
    assert!(snap.coord.is_empty());
    assert!(lane_timelines(&snap).is_empty());
}
