//! Integration: full pipelines over the real compiled artifacts.
//! Each test self-skips when artifacts/ has not been built.

use sada::baselines::{AdaptiveDiffusion, DeepCache, TeaCache};
use sada::metrics::psnr;
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline, StepMode};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::{Sada, SadaConfig};
use sada::solvers::SolverKind;
use sada::tensor::ops;
use sada::workload::PromptBank;

fn runtime() -> Option<Runtime> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some(Runtime::open("artifacts").expect("runtime opens"))
    } else {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        None
    }
}

fn request(rt: &Runtime, idx: usize, steps: usize) -> GenRequest {
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), rt.manifest.cond_dim);
    GenRequest {
        cond: bank.get(idx).clone(),
        seed: bank.seed_for(idx),
        guidance: 3.0,
        steps,
        edge: None,
    }
}

#[test]
fn baseline_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let req = request(&rt, 0, 20);
    let a = pipe.generate(&req, &mut NoAccel).unwrap();
    let b = pipe.generate(&req, &mut NoAccel).unwrap();
    assert_eq!(a.image.data(), b.image.data());
}

#[test]
fn sada_reduces_nfe_and_stays_faithful() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let req = request(&rt, 1, 50);
    let base = pipe.generate(&req, &mut NoAccel).unwrap();
    let mut sada = Sada::with_default(backend.info(), 50);
    let fast = pipe.generate(&req, &mut sada).unwrap();
    assert!(fast.stats.nfe < 40, "nfe={} trace={}", fast.stats.nfe, fast.stats.mode_trace());
    let p = psnr(&decode::finalize(&base.image), &decode::finalize(&fast.image));
    assert!(p > 18.0, "psnr={p}, trace={}", fast.stats.mode_trace());
}

#[test]
fn token_prune_variant_executes() {
    // force token-wise decisions by disabling step skips
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let req = request(&rt, 2, 30);
    use sada::pipeline::{Accelerator, KeepMask, StepCtx, StepObs, StepPlan};
    struct ForcePrune;
    impl Accelerator for ForcePrune {
        fn name(&self) -> String {
            "force-prune".into()
        }
        fn plan(&mut self, ctx: &StepCtx) -> StepPlan {
            if ctx.i % 2 == 1 && ctx.have_caches {
                StepPlan::Prune {
                    mask: std::sync::Arc::new(KeepMask {
                        variant: "prune50".into(),
                        keep_idx: (0..32).collect(),
                    }),
                }
            } else {
                StepPlan::Full
            }
        }
        fn observe(&mut self, _o: &StepObs) {}
        fn reset(&mut self) {}
        fn clone_fresh(&self) -> Box<dyn Accelerator> {
            Box::new(ForcePrune)
        }
    }
    let base = pipe.generate(&req, &mut NoAccel).unwrap();
    let res = pipe.generate(&req, &mut ForcePrune).unwrap();
    assert!(res.stats.count(StepMode::Prune) > 10);
    // pruned attention with cache reconstruction stays close to baseline
    let p = psnr(&decode::finalize(&base.image), &decode::finalize(&res.image));
    assert!(p > 15.0, "prune path drifted: psnr={p}");
}

#[test]
fn deepcache_shallow_variant_executes() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sdxl_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::Euler);
    let req = request(&rt, 3, 20);
    let base = pipe.generate(&req, &mut NoAccel).unwrap();
    let mut dc = DeepCache::new(3);
    let res = pipe.generate(&req, &mut dc).unwrap();
    assert!(res.stats.count(StepMode::Shallow) > 5);
    let p = psnr(&decode::finalize(&base.image), &decode::finalize(&res.image));
    assert!(p > 12.0, "deepcache drifted: psnr={p}");
}

#[test]
fn flux_flow_pipeline_works() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("flux_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::Flow);
    let req = request(&rt, 4, 30);
    let base = pipe.generate(&req, &mut NoAccel).unwrap();
    assert!(ops::norm2(&base.image) > 1e-3);
    let mut tc = TeaCache::default();
    let t = pipe.generate(&req, &mut tc).unwrap();
    let mut sada = Sada::with_default(backend.info(), 30);
    let s = pipe.generate(&req, &mut sada).unwrap();
    assert!(s.stats.nfe <= 30);
    assert!(t.stats.nfe <= 30);
    assert!(decode::finalize(&s.image).data().iter().all(|v| v.is_finite()));
}

#[test]
fn music_and_control_models_generate() {
    let Some(rt) = runtime() else { return };
    // music
    let backend = rt.model_backend("music_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let mut req = request(&rt, 5, 15);
    let m = pipe.generate(&req, &mut NoAccel).unwrap();
    assert_eq!(m.image.shape(), &[1, 16, 64, 1]);
    // control (requires edge)
    let backend = rt.model_backend("control_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let edges = sada::exp::controlnet::load_edges("artifacts").unwrap();
    req.edge = Some(edges[0].clone());
    let c = pipe.generate(&req, &mut NoAccel).unwrap();
    assert_eq!(c.image.shape(), &[1, 16, 16, 3]);
    // missing edge must error, not crash
    req.edge = None;
    assert!(pipe.generate(&req, &mut NoAccel).is_err());
}

#[test]
fn batched_variant_matches_sequential() {
    // a 4-lane batch gathers into full_b4 (uniform guidance, one group)
    // and must equal 4 independent full runs — the lane engine is the
    // only batched execution path (lockstep generate_batch is retired)
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let reqs: Vec<GenRequest> = (0..4).map(|i| request(&rt, i, 10)).collect();
    use sada::pipeline::Accelerator;
    let proto: &dyn Accelerator = &NoAccel;
    let batched = pipe.generate_lanes(&reqs, proto).unwrap();
    for (i, r) in reqs.iter().enumerate() {
        let solo = pipe.generate(r, &mut NoAccel).unwrap();
        let mse = ops::mse(&solo.image, &batched[i].image);
        assert!(mse < 1e-6, "request {i}: batched vs solo mse={mse}");
    }
}

#[test]
fn lane_engine_matches_sequential_without_exact_bucket() {
    // batch of 3 has no compiled full_b3: the lane engine must split the
    // gather across smaller buckets / singles and still match sequential
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::with_schedule(
        &backend,
        SolverKind::DpmPP,
        rt.manifest.schedule.to_schedule(),
    );
    let reqs: Vec<GenRequest> = (0..3).map(|i| request(&rt, i, 10)).collect();
    use sada::pipeline::Accelerator;
    let proto: &dyn Accelerator = &NoAccel;
    let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
    assert_eq!(lanes.len(), 3);
    for (i, r) in reqs.iter().enumerate() {
        let solo = pipe.generate(r, &mut NoAccel).unwrap();
        let mse = ops::mse(&solo.image, &lanes[i].image);
        assert!(mse < 1e-6, "lane {i}: lanes vs solo mse={mse}");
        assert_eq!(lanes[i].stats.nfe, solo.stats.nfe, "lane {i} NFE");
    }
}

#[test]
fn lane_engine_sada_reports_per_lane_stats_on_artifacts() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::with_schedule(
        &backend,
        SolverKind::DpmPP,
        rt.manifest.schedule.to_schedule(),
    );
    let mut reqs: Vec<GenRequest> = (0..3).map(|i| request(&rt, i, 30)).collect();
    // divergent guidance per lane: the lane engine sub-batches per gs
    // (the retired lockstep path required uniform guidance)
    reqs[0].guidance = 1.0;
    reqs[1].guidance = 4.0;
    reqs[2].guidance = 8.0;
    use sada::pipeline::Accelerator;
    let proto = Sada::with_default(backend.info(), 30);
    let proto: &dyn Accelerator = &proto;
    let lanes = pipe.generate_lanes(&reqs, proto).unwrap();
    for (i, lane) in lanes.iter().enumerate() {
        assert_eq!(lane.stats.modes.len(), 30, "lane {i}");
        assert_eq!(lane.stats.nfe, lane.stats.fresh_steps, "lane {i}");
        assert!(lane.image.data().iter().all(|v| v.is_finite()), "lane {i}");
    }
}

#[test]
fn adaptive_diffusion_runs_on_artifacts() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::Euler);
    let req = request(&rt, 6, 30);
    let mut ad = AdaptiveDiffusion::default();
    let r = pipe.generate(&req, &mut ad).unwrap();
    assert_eq!(r.stats.modes.len(), 30);
}

#[test]
fn sada_ablation_no_multistep_on_artifacts() {
    let Some(rt) = runtime() else { return };
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let req = request(&rt, 7, 30);
    let mut cfg = SadaConfig::default();
    cfg.enable_multistep = false;
    let mut sada = Sada::new(backend.info(), cfg);
    let r = pipe.generate(&req, &mut sada).unwrap();
    assert_eq!(r.stats.count(StepMode::SkipLagrange), 0);
}
