//! Property tests of the SADA accelerator over the analytic GM backend:
//! randomized seeds/steps, invariants that must hold for every trajectory.

use sada::pipeline::{GenRequest, NoAccel, Pipeline, StepMode};
use sada::runtime::mock::GmBackend;
use sada::runtime::ModelBackend;
use sada::sada::{Sada, SadaConfig};
use sada::solvers::SolverKind;
use sada::tensor::{ops, Tensor};
use sada::testutil::{check, Gen, UsizeIn};

struct Case {
    seed: u64,
    steps: usize,
    solver: SolverKind,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Case(seed={}, steps={}, solver={})", self.seed, self.steps, self.solver.name())
    }
}

impl Clone for Case {
    fn clone(&self) -> Self {
        Case { seed: self.seed, steps: self.steps, solver: self.solver }
    }
}

struct CaseGen;

impl Gen for CaseGen {
    type Value = Case;
    fn generate(&self, rng: &mut sada::rng::Rng) -> Case {
        let steps = UsizeIn(10, 60).generate(rng);
        let solver = if rng.below(2) == 0 { SolverKind::Euler } else { SolverKind::DpmPP };
        Case { seed: rng.next_u64(), steps, solver }
    }
}

fn req(seed: u64, steps: usize) -> GenRequest {
    let mut rng = sada::rng::Rng::new(seed ^ 0xABCD);
    GenRequest {
        cond: Tensor::from_rng(&mut rng, &[1, 32]),
        seed,
        guidance: 2.0,
        steps,
        edge: None,
    }
}

#[test]
fn prop_sada_invariants_hold_across_cases() {
    let backend = GmBackend::new(17);
    check(99, 25, &CaseGen, |case| {
        let pipe = Pipeline::new(&backend, case.solver);
        let r = req(case.seed, case.steps);
        let base = pipe.generate(&r, &mut NoAccel).map_err(|e| e.to_string())?;
        let mut accel = Sada::with_default(backend.info(), case.steps);
        let fast = pipe.generate(&r, &mut accel).map_err(|e| e.to_string())?;

        // 1. step accounting is exact
        if fast.stats.modes.len() != case.steps {
            return Err(format!("recorded {} modes for {} steps", fast.stats.modes.len(), case.steps));
        }
        // 2. boundary steps always full
        if fast.stats.modes[0] != StepMode::Full || *fast.stats.modes.last().unwrap() != StepMode::Full {
            return Err(format!("boundary not full: {}", fast.stats.mode_trace()));
        }
        // 3. NFE never exceeds the baseline
        if fast.stats.nfe > base.stats.nfe {
            return Err("sada used more NFE than baseline".into());
        }
        // 4. output finite and bounded relative to baseline scale
        if !fast.image.data().iter().all(|v| v.is_finite()) {
            return Err("non-finite output".into());
        }
        let rmse = ops::mse(&base.image, &fast.image).sqrt();
        let scale = ops::norm2(&base.image) / (base.image.len() as f64).sqrt();
        if rmse > 1.0 * scale.max(0.2) {
            return Err(format!(
                "diverged: rmse={rmse:.4} scale={scale:.4} trace={}",
                fast.stats.mode_trace()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_warmup_respected_for_all_configs() {
    let backend = GmBackend::new(23);
    check(7, 15, &UsizeIn(1, 6), |warmup| {
        let mut cfg = SadaConfig::default();
        cfg.warmup = *warmup;
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let mut accel = Sada::new(backend.info(), cfg);
        let r = req(5, 20);
        let fast = pipe.generate(&r, &mut accel).map_err(|e| e.to_string())?;
        for (i, m) in fast.stats.modes.iter().enumerate().take(*warmup.min(&20)) {
            if *m != StepMode::Full {
                return Err(format!("step {i} not full during warmup {warmup}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_determinism_across_repeats() {
    let backend = GmBackend::new(29);
    check(3, 10, &UsizeIn(10, 40), |steps| {
        let pipe = Pipeline::new(&backend, SolverKind::Euler);
        let r = req(11, *steps);
        let mut a1 = Sada::with_default(backend.info(), *steps);
        let mut a2 = Sada::with_default(backend.info(), *steps);
        let r1 = pipe.generate(&r, &mut a1).map_err(|e| e.to_string())?;
        let r2 = pipe.generate(&r, &mut a2).map_err(|e| e.to_string())?;
        if r1.image.data() != r2.image.data() {
            return Err("nondeterministic output".into());
        }
        if r1.stats.mode_trace() != r2.stats.mode_trace() {
            return Err("nondeterministic mode trace".into());
        }
        Ok(())
    });
}
