//! Allocation-regression test: steady-state lane steps allocate nothing.
//!
//! A thread-local counting allocator wraps the system allocator; the test
//! runs the same lane batch at two step counts and asserts the allocation
//! totals are identical — any per-step allocation would show up as (at
//! least) one count per extra step. Init-time allocations (lane buffers,
//! solver grids, stats vectors) are identical between the two runs by
//! construction, so the difference isolates exactly the step loop.
//!
//! This file intentionally contains few tests: the counter is per-thread
//! (the cargo test harness runs tests on separate threads), so each test
//! observes only its own allocations.

// the GlobalAlloc bodies call straight into `System`; keep them lint-clean
// on every edition's unsafe-in-unsafe-fn rules
#![allow(unsafe_op_in_unsafe_fn)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        // try_with: never panic during TLS teardown
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    LOCAL_ALLOCS.with(|c| c.get())
}

use sada::pipeline::{Accelerator, GenRequest, NoAccel, Pipeline};
use sada::runtime::mock::GmBackend;
use sada::sada::{Sada, SadaConfig};
use sada::solvers::SolverKind;
use sada::tensor::Tensor;

fn reqs_for(n: usize, steps: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = sada::rng::Rng::new(seed);
    (0..n)
        .map(|_| GenRequest {
            cond: Tensor::from_rng(&mut rng, &[1, 32]),
            seed: rng.below(100_000),
            guidance: 3.0, // one guidance group: maximal bucket gathering
            steps,
            edge: None,
        })
        .collect()
}

#[test]
fn steady_state_lane_steps_allocate_nothing() {
    let backend = GmBackend::with_batch_buckets(5, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let proto: &dyn Accelerator = &NoAccel;

    // warm every pool: the arena's bucket buffers, the backend scratch,
    // solver scratch, and the arena's shape-pool hash map
    let warm = pipe.generate_lanes(&reqs_for(5, 12, 301), proto).unwrap();
    assert_eq!(warm.len(), 5);

    let run = |steps: usize| -> u64 {
        let reqs = reqs_for(5, steps, 301);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| r.stats.nfe == steps));
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "steady-state lane steps must allocate nothing: 20 extra steps cost {} allocation(s)",
        long.saturating_sub(short)
    );
    // and the arena actually carried the bucket traffic: every steady-state
    // checkout was a pool hit
    let stats = pipe.arena_stats();
    assert!(stats.checkouts > 0, "bucketed run must use the arena");
    assert!(
        stats.misses <= 3,
        "arena misses beyond the warmup shapes: {stats:?}"
    );
}

#[test]
fn sada_lane_steps_allocate_o1_not_per_step() {
    // SADA's steady state — criterion scratch, AM-3 skips, pooled history,
    // multistep Lagrange reconstruction — through the same marginal-cost
    // lens. Token-wise pruning is disabled (its mask selection is
    // legitimately allocating and compiled at batch 1); a small slack
    // absorbs amortized growth in long-lived Vecs.
    let backend = GmBackend::with_batch_buckets(9, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);

    let run = |steps: usize| -> u64 {
        let mut cfg = SadaConfig::default().for_steps(steps);
        cfg.enable_tokenwise = false;
        let proto = Sada::new(backend.info(), cfg);
        let proto: &dyn Accelerator = &proto;
        // warm with the same configuration, then measure
        pipe.generate_lanes(&reqs_for(4, steps, 77), proto).unwrap();
        let reqs = reqs_for(4, steps, 77);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 4);
        after - before
    };
    let short = run(30);
    let long = run(60);
    // Slack rationale: per-run state (history ramps, criterion scratch,
    // diags reserve) is identical between the runs; the only legitimate
    // residual traffic is aux-slot churn when a lane moves between single
    // and bucketed execution (bounded by composition changes, not steps).
    // The pre-arena path cost >5 allocations per lane per step (~600 over
    // the 30 extra steps), so this bound still pins the regression hard.
    assert!(
        long <= short + 48,
        "SADA lane steps must not allocate per step: 30 extra steps cost {} allocation(s) \
         (short run: {short})",
        long.saturating_sub(short)
    );
}
