//! Allocation-regression test: steady-state lane steps allocate nothing.
//!
//! A thread-local counting allocator wraps the system allocator; the test
//! runs the same lane batch at two step counts and asserts the allocation
//! totals are identical — any per-step allocation would show up as (at
//! least) one count per extra step. Init-time allocations (lane buffers,
//! solver grids, stats vectors) are identical between the two runs by
//! construction, so the difference isolates exactly the step loop.
//!
//! This file intentionally contains few tests: the counter is per-thread
//! (the cargo test harness runs tests on separate threads), so each test
//! observes only its own allocations.

use sada::testutil::alloc::{thread_allocs, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

use sada::pipeline::{Accelerator, GenRequest, KeepMask, NoAccel, Pipeline};
use sada::pipeline::{StepCtx, StepObs, StepPlan};
use sada::runtime::mock::GmBackend;
use sada::sada::{Sada, SadaConfig};
use sada::solvers::SolverKind;
use sada::tensor::Tensor;
use std::sync::Arc;

/// Deterministic prune-heavy schedule over one shared keep mask: Full
/// while the lane's caches are cold, then Prune every other step. The
/// mask handoff is an Arc refcount bump, so the accelerator itself is
/// allocation-free at plan time.
struct ScriptedPrune {
    mask: Arc<KeepMask>,
}
impl Accelerator for ScriptedPrune {
    fn name(&self) -> String {
        "scripted-prune".into()
    }
    fn plan(&mut self, ctx: &StepCtx) -> StepPlan {
        if ctx.have_caches && ctx.i % 2 == 1 {
            StepPlan::Prune { mask: self.mask.clone() }
        } else {
            StepPlan::Full
        }
    }
    fn observe(&mut self, _o: &StepObs) {}
    fn wants_obs(&self) -> bool {
        false
    }
    fn reset(&mut self) {}
    fn clone_fresh(&self) -> Box<dyn Accelerator> {
        Box::new(ScriptedPrune { mask: self.mask.clone() })
    }
}

fn reqs_for(n: usize, steps: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = sada::rng::Rng::new(seed);
    (0..n)
        .map(|_| GenRequest {
            cond: Tensor::from_rng(&mut rng, &[1, 32]),
            seed: rng.below(100_000),
            guidance: 3.0, // one guidance group: maximal bucket gathering
            steps,
            edge: None,
        })
        .collect()
}

#[test]
fn steady_state_lane_steps_allocate_nothing() {
    let backend = GmBackend::with_batch_buckets(5, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let proto: &dyn Accelerator = &NoAccel;

    // warm every pool: the arena's bucket buffers, the backend scratch,
    // solver scratch, and the arena's shape-pool hash map
    let warm = pipe.generate_lanes(&reqs_for(5, 12, 301), proto).unwrap();
    assert_eq!(warm.len(), 5);

    let run = |steps: usize| -> u64 {
        let reqs = reqs_for(5, steps, 301);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| r.stats.nfe == steps));
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "steady-state lane steps must allocate nothing: 20 extra steps cost {} allocation(s)",
        long.saturating_sub(short)
    );
    // and the arena actually carried the bucket traffic: every steady-state
    // checkout was a pool hit. Warm-run misses: the bucket-4 gather shapes
    // (x + out share one shape, cond another: 3), the batch-major aux
    // capture buffers a bucketed full launch checks out (deep_b + caches_b:
    // 2), plus the five lanes' retained aux slots (deep + caches shapes,
    // five concurrent checkouts each before any release: 10)
    let stats = pipe.arena_stats();
    assert!(stats.checkouts > 0, "bucketed run must use the arena");
    assert!(
        stats.misses <= 15,
        "arena misses beyond the warmup shapes: {stats:?}"
    );
}

#[test]
fn prune_heavy_lane_steps_allocate_nothing_at_steady_state() {
    // the token-pruned arm of the step loop under the aux-slot discipline:
    // the keep-mask handoff is an Arc refcount bump, the input caches
    // buffer retires to the arena, and the refreshed caches land in an
    // arena buffer the backend fills in place — so a prune-heavy schedule
    // is as allocation-free as the Full path (this is the replay shape a
    // cache-warm lane executes when token directives replay natively)
    let backend = GmBackend::new(7);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let mask = Arc::new(KeepMask { variant: "prune50".into(), keep_idx: (0..8).collect() });
    let proto = ScriptedPrune { mask };
    let proto: &dyn Accelerator = &proto;
    // warm every pool: lane buffers, retained aux slots, and the
    // prune-refresh caches shape
    pipe.generate_lanes(&reqs_for(3, 12, 55), proto).unwrap();

    let run = |steps: usize| -> u64 {
        let reqs = reqs_for(3, steps, 55);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 3);
        for r in &out {
            assert!(
                r.stats.count(sada::pipeline::StepMode::Prune) >= steps / 2 - 1,
                "schedule must be prune-heavy: trace={}",
                r.stats.mode_trace()
            );
            assert_eq!(r.stats.degraded.prune, 0, "caches stay valid lane-locally");
        }
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "prune-heavy steady state must allocate nothing: 20 extra steps cost {} allocation(s)",
        long.saturating_sub(short)
    );
}

#[test]
fn batched_prune_steps_allocate_nothing_at_steady_state() {
    // the degraded-variant bucket path: four aligned prune-heavy lanes
    // gather into compiled `prune50_b4` / `full_b4` launches every step —
    // cache rows gather into, and refreshed rows scatter out of,
    // arena-backed batch-major buffers — and the steady state must be as
    // allocation-free as the singles path
    let backend = GmBackend::with_variant_buckets(17, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let mask = Arc::new(KeepMask { variant: "prune50".into(), keep_idx: (0..8).collect() });
    let proto = ScriptedPrune { mask };
    let proto: &dyn Accelerator = &proto;
    // warm every pool: the batch-4 gather shapes (x/out, cond, caches,
    // refreshed caches, deep) plus the lanes' retained aux slots
    pipe.generate_lanes(&reqs_for(4, 12, 61), proto).unwrap();

    let run = |steps: usize| -> u64 {
        let reqs = reqs_for(4, steps, 61);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 4);
        for r in &out {
            assert!(
                r.stats.count(sada::pipeline::StepMode::Prune) >= steps / 2 - 1,
                "schedule must be prune-heavy: trace={}",
                r.stats.mode_trace()
            );
            assert_eq!(r.stats.degraded.prune, 0, "caches stay valid lane-locally");
            // all four lanes stay aligned on one variant signature, so
            // every fresh step rides a compiled bucket — nothing falls
            // back to singles
            assert_eq!(r.stats.mix.batched, r.stats.nfe, "mix {:?}", r.stats.mix);
            assert_eq!(r.stats.mix.singles(), 0, "mix {:?}", r.stats.mix);
        }
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "batched-prune steady state must allocate nothing: 20 extra steps cost {} allocation(s)",
        long.saturating_sub(short)
    );
}

#[test]
fn midflight_admission_is_o1_and_steady_steps_allocate_nothing() {
    // Continuous engine: 4 requests stream through 2 slots, the feeder
    // admitting one lane per freed slot. Admission events (4 in both runs)
    // are bounded per-event costs — solver grid, stats vector, accel box —
    // whose allocation COUNTS are step-count-independent, so comparing the
    // totals at 12 vs 32 steps isolates the per-step cost of the running
    // engine, admissions included. Steady-state steps must allocate zero.
    use sada::pipeline::{AdmittedLane, GenResult, LaneFeeder};
    use std::collections::VecDeque;

    struct StaggerFeeder {
        pending: VecDeque<GenRequest>,
        results: Vec<Option<GenResult>>,
        next_tag: u64,
    }
    impl LaneFeeder for StaggerFeeder {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some(req) = self.pending.pop_front() else { return Vec::new() };
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel: Box::new(NoAccel), tag }]
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            if let Some(slot) = self.results.get_mut(tag as usize) {
                *slot = Some(result);
            }
        }
    }

    let backend = GmBackend::with_batch_buckets(11, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let feeder_for = |steps: usize| StaggerFeeder {
        pending: reqs_for(4, steps, 901).into(),
        results: (0..4).map(|_| None).collect(),
        next_tag: 0,
    };

    // warm every pool, including the admission-reuse path for slots freed
    // mid-flight (lanes 2 and 3 re-fill the slots lanes 0 and 1 vacate)
    {
        let mut f = feeder_for(12);
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        assert_eq!(stats.admitted, 4);
        assert_eq!(stats.completed, 4);
    }

    let run = |steps: usize| -> u64 {
        let mut f = feeder_for(steps);
        let before = thread_allocs();
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        let after = thread_allocs();
        assert_eq!(stats.admitted, 4, "feeder must stream all requests in");
        assert_eq!(stats.completed, 4);
        assert!(
            f.results
                .iter()
                .all(|r| r.as_ref().is_some_and(|g| g.stats.nfe == steps)),
            "every lane must run its full solo trajectory"
        );
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "continuous-engine steady state must allocate nothing: 20 extra steps across \
         4 streamed lanes cost {} allocation(s)",
        long.saturating_sub(short)
    );
}

#[test]
fn preempt_resume_cycle_is_o1_and_steady_steps_allocate_nothing() {
    // Lane preemption rides the same 4-requests-through-2-slots stream as
    // midflight_admission_is_o1...: each measured run preempts lane 0
    // mid-flight, parks its checkpoint for a few engine steps, and
    // resumes it into the next freed slot. The checkpoint/restore pair is
    // a bounded per-event cost (standby buffers check out of the warmed
    // arena, dummy solver/req swap-ins) whose allocation COUNT is
    // step-count-independent — identical in both runs, so comparing
    // totals at 12 vs 32 steps isolates the per-step cost. Steady-state
    // steps with preemption enabled must allocate zero.
    use sada::pipeline::{AdmittedLane, GenResult, LaneCheckpoint, LaneFeeder, LaneStatus};
    use std::collections::VecDeque;

    struct PreemptFeeder {
        pending: VecDeque<GenRequest>,
        results: Vec<Option<GenResult>>,
        next_tag: u64,
        calls: usize,
        parked: Option<(LaneCheckpoint, usize)>,
        fired: bool,
    }
    impl LaneFeeder for PreemptFeeder {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some(req) = self.pending.pop_front() else { return Vec::new() };
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel: Box::new(NoAccel), tag }]
        }
        fn plan_preemptions(&mut self, lanes: &[LaneStatus]) -> Vec<(u64, f64)> {
            self.calls += 1;
            if !self.fired && self.calls >= 4 && lanes.iter().any(|l| l.tag == 0 && l.step > 0)
            {
                self.fired = true;
                return vec![(0, -1.0)];
            }
            Vec::new()
        }
        fn preempted(&mut self, ckpt: LaneCheckpoint) {
            self.parked = Some((ckpt, self.calls));
        }
        fn resume(&mut self, free: usize) -> Vec<(LaneCheckpoint, f64)> {
            if free == 0 {
                return Vec::new();
            }
            if let Some((ckpt, at)) = self.parked.take() {
                if self.calls >= at + 3 || self.pending.is_empty() {
                    return vec![(ckpt, 1.0)];
                }
                self.parked = Some((ckpt, at));
            }
            Vec::new()
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            if let Some(slot) = self.results.get_mut(tag as usize) {
                *slot = Some(result);
            }
        }
    }

    let backend = GmBackend::with_batch_buckets(17, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let feeder_for = |steps: usize| PreemptFeeder {
        pending: reqs_for(4, steps, 907).into(),
        results: (0..4).map(|_| None).collect(),
        next_tag: 0,
        calls: 0,
        parked: None,
        fired: false,
    };

    // warm every pool, the checkpoint standby-buffer shapes included
    {
        let mut f = feeder_for(12);
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        assert_eq!(stats.preempted, 1);
        assert_eq!(stats.resumed, 1);
    }

    let run = |steps: usize| -> u64 {
        let mut f = feeder_for(steps);
        let before = thread_allocs();
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        let after = thread_allocs();
        assert_eq!(stats.admitted, 4, "feeder must stream all requests in");
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.preempted, 1, "the scripted preemption must fire");
        assert_eq!(stats.resumed, 1, "the parked checkpoint must resume");
        assert!(
            f.results
                .iter()
                .all(|r| r.as_ref().is_some_and(|g| g.stats.nfe == steps)),
            "every lane must run its full solo trajectory"
        );
        after - before
    };
    let short = run(12);
    let long = run(32);
    assert_eq!(
        long,
        short,
        "preemption-enabled steady state must allocate nothing: 20 extra steps \
         cost {} allocation(s)",
        long.saturating_sub(short)
    );
}

#[test]
fn full_recorder_steady_steps_allocate_nothing() {
    // The flight recorder in `full` mode rides the same continuous run as
    // midflight_admission_is_o1...: every lane step now also records a
    // Step event (plus per-engine-step phase flushes), and the totals at
    // 12 vs 32 steps must still be identical — ring pushes are wrapping
    // stores into preallocated buffers. Each measured run gets a fresh
    // recorder, so the per-run session begin/end cost (ring preallocation,
    // archive push) is identical by construction and cancels out.
    use sada::obs::{summary, FlightRecorder, Sampling};
    use sada::pipeline::{AdmittedLane, ContinuousStats, GenResult, LaneFeeder};
    use std::collections::VecDeque;
    use std::sync::Arc;

    struct StreamFeeder {
        pending: VecDeque<GenRequest>,
        results: Vec<Option<GenResult>>,
        next_tag: u64,
    }
    impl LaneFeeder for StreamFeeder {
        fn admit(&mut self, free: usize) -> Vec<AdmittedLane> {
            if free == 0 {
                return Vec::new();
            }
            let Some(req) = self.pending.pop_front() else { return Vec::new() };
            let tag = self.next_tag;
            self.next_tag += 1;
            vec![AdmittedLane { req, accel: Box::new(NoAccel), tag }]
        }
        fn complete(&mut self, tag: u64, result: GenResult) {
            if let Some(slot) = self.results.get_mut(tag as usize) {
                *slot = Some(result);
            }
        }
    }

    let backend = GmBackend::with_batch_buckets(13, &[2, 4]);
    let mut pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let feeder_for = |steps: usize| StreamFeeder {
        pending: reqs_for(4, steps, 901).into(),
        results: (0..4).map(|_| None).collect(),
        next_tag: 0,
    };

    // warm every pool with the recorder attached
    {
        let rec = FlightRecorder::with_capacity(Sampling::Full, 256, 512);
        pipe.set_flight_recorder(rec, 0);
        let mut f = feeder_for(12);
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        assert_eq!(stats.completed, 4);
    }

    let mut run = |steps: usize| -> (u64, Arc<FlightRecorder>, ContinuousStats) {
        let rec = FlightRecorder::with_capacity(Sampling::Full, 256, 512);
        pipe.set_flight_recorder(rec.clone(), 0);
        let mut f = feeder_for(steps);
        let before = thread_allocs();
        let stats = pipe.generate_continuous(2, &mut f).unwrap();
        let after = thread_allocs();
        assert_eq!(stats.admitted, 4);
        assert_eq!(stats.completed, 4);
        (after - before, rec, stats)
    };
    let (short, _, _) = run(12);
    let (long, rec, stats) = run(32);
    assert_eq!(
        long,
        short,
        "full-mode recording must stay zero-alloc per step: 20 extra steps across \
         4 streamed lanes cost {} allocation(s)",
        long.saturating_sub(short)
    );
    // and the recording is complete, not silently sampled away: the long
    // run's timelines reconstruct the engine's own accounting exactly
    let snap = rec.take_snapshot();
    let tls = summary::lane_timelines(&snap);
    assert_eq!(tls.len(), 4);
    let mut lane_steps = 0usize;
    for tl in &tls {
        summary::check_timeline(tl).unwrap();
        lane_steps += tl.steps.len();
    }
    assert_eq!(lane_steps, stats.lane_steps);
}

#[test]
fn sada_lane_steps_allocate_o1_not_per_step() {
    // SADA's steady state — criterion scratch, AM-3 skips, pooled history,
    // multistep Lagrange reconstruction — through the same marginal-cost
    // lens. Token-wise pruning is disabled (its mask selection is
    // legitimately allocating and compiled at batch 1); a small slack
    // absorbs amortized growth in long-lived Vecs.
    let backend = GmBackend::with_batch_buckets(9, &[2, 4]);
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);

    let run = |steps: usize| -> u64 {
        let mut cfg = SadaConfig::default().for_steps(steps);
        cfg.enable_tokenwise = false;
        let proto = Sada::new(backend.info(), cfg);
        let proto: &dyn Accelerator = &proto;
        // warm with the same configuration, then measure
        pipe.generate_lanes(&reqs_for(4, steps, 77), proto).unwrap();
        let reqs = reqs_for(4, steps, 77);
        let before = thread_allocs();
        let out = pipe.generate_lanes(&reqs, proto).unwrap();
        let after = thread_allocs();
        assert_eq!(out.len(), 4);
        after - before
    };
    let short = run(30);
    let long = run(60);
    // Slack rationale: per-run state (history ramps, criterion scratch,
    // diags reserve) is identical between the runs; the only legitimate
    // residual traffic is aux-slot churn when a lane moves between single
    // and bucketed execution (bounded by composition changes, not steps).
    // The pre-arena path cost >5 allocations per lane per step (~600 over
    // the 30 extra steps), so this bound still pins the regression hard.
    assert!(
        long <= short + 48,
        "SADA lane steps must not allocate per step: 30 extra steps cost {} allocation(s) \
         (short run: {short})",
        long.saturating_sub(short)
    );
}
