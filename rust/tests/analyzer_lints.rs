//! Fixture tests for the invariant analyzer: seeded violations are flagged
//! by every pass, clean shapes pass, `// xtask: allow(...)` suppresses and
//! is counted — and the crate itself analyzes clean (the same check the
//! xtask CI gate enforces).
//!
//! Fixture paths matter: roots are registered by qualified name
//! (`server::worker_loop`, `Pipeline::generate`), and the lock passes are
//! scoped to `coordinator/` — so fixtures use those virtual paths.

use std::path::Path;

use sada::analysis::{analyze_sources, Report};

fn files(src: &str) -> Vec<(String, String)> {
    vec![("coordinator/server.rs".to_string(), src.to_string())]
}

fn pass_findings<'r>(r: &'r Report, pass: &str) -> Vec<&'r sada::analysis::passes::Finding> {
    r.findings.iter().filter(|f| f.pass == pass).collect()
}

/// One file seeding a violation for each of the four passes.
const BAD: &str = r#"
use std::sync::Mutex;

pub struct S { a: Mutex<u32>, b: Mutex<u32>, tx: std::sync::mpsc::Sender<u32> }

impl S {
    pub fn ab(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb); drop(ga);
    }
    pub fn ba(&self) {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        drop(ga); drop(gb);
    }
    pub fn held_send(&self) {
        let g = self.a.lock().unwrap();
        self.tx.send(*g).unwrap();
    }
}

pub fn worker_loop(s: &S) {
    s.ab(); s.ba(); s.held_send();
    let v = vec![1, 2, 3];
    let _ = v[10];
}

pub struct Pipeline;
impl Pipeline {
    pub fn generate(&self) -> Vec<u32> {
        let out = Vec::new();
        helper_into(&mut []);
        out
    }
}

pub fn helper(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    for x in xs { out.push(*x + 1.0); }
    out
}
pub fn helper_into(_out: &mut [f32]) {}
"#;

#[test]
fn seeded_violations_are_flagged_by_every_pass() {
    let r = analyze_sources(&files(BAD));
    assert!(!r.clean());
    let hot = pass_findings(&r, "hot_alloc");
    assert!(
        hot.iter().any(|f| f.function == "Pipeline::generate" && f.message.contains("Vec::new")),
        "{hot:?}"
    );
    let pairing = pass_findings(&r, "into_pairing");
    assert!(
        pairing.iter().any(|f| f.message.contains("does not delegate")),
        "{pairing:?}"
    );
    assert!(pairing.iter().any(|f| f.message.contains("loop")), "{pairing:?}");
    let locks = pass_findings(&r, "lock_order");
    assert!(locks.iter().any(|f| f.message.contains("cycle")), "{locks:?}");
    assert!(
        locks.iter().any(|f| f.message.contains("blocking call .send()")),
        "{locks:?}"
    );
    let panics = pass_findings(&r, "panic_safety");
    assert!(
        panics.iter().any(|f| f.message.contains(".unwrap()")),
        "{panics:?}"
    );
    assert!(
        panics.iter().any(|f| f.message.contains("slice indexing")),
        "{panics:?}"
    );
}

#[test]
fn clean_shapes_produce_no_findings() {
    // consistent lock order, thin delegating wrapper, allocation-free hot
    // root, panic-free worker path
    let good = r#"
use std::sync::Mutex;

pub struct S { a: Mutex<u32>, b: Mutex<u32> }

impl S {
    pub fn both_ab(&self) -> u32 {
        let ga = lock_ignore_poison(&self.a);
        let gb = lock_ignore_poison(&self.b);
        *ga + *gb
    }
    pub fn sum_ab(&self) -> u32 {
        let ga = lock_ignore_poison(&self.a);
        let gb = lock_ignore_poison(&self.b);
        *ga * *gb
    }
}

pub fn worker_loop(s: &S) -> u32 {
    s.both_ab() + s.sum_ab()
}

pub struct Pipeline;
impl Pipeline {
    pub fn generate(&self, buf: &mut [f32]) {
        lincomb_into(buf, 2.0);
    }
}

pub fn lincomb(xs: &[f32], k: f32) -> Vec<f32> {
    let mut out = vec![0.0; xs.len()];
    lincomb_into(&mut out, k);
    out
}
pub fn lincomb_into(out: &mut [f32], k: f32) {
    for o in out.iter_mut() { *o += k; }
}
"#;
    let r = analyze_sources(&files(good));
    assert!(r.clean(), "{}", r.render_text());
    // the wrapper/twin pair was actually checked, not skipped
    let pairing = r.summaries.iter().find(|s| s.name == "into_pairing").unwrap();
    assert_eq!(pairing.meta, 1, "lincomb/lincomb_into should register as a pair");
    // both locks were seen and ordered consistently: 1 distinct edge a->b
    let locks = r.summaries.iter().find(|s| s.name == "lock_order").unwrap();
    assert!(locks.meta >= 1, "expected at least one lock-order edge");
}

#[test]
fn allow_directives_suppress_and_are_counted() {
    let annotated = r#"
pub struct Pipeline;
impl Pipeline {
    pub fn generate(&self) {
        // xtask: allow(alloc): warm-up scratch, once per run
        let scratch = Vec::with_capacity(8);
        advance(&scratch);
    }
}
pub fn advance(_s: &[f32]) {
    let x: Option<u32> = Some(1);
    // xtask: allow(panic): invariant — always Some here
    let _ = x.unwrap();
}
pub fn worker_loop() { advance(&[]); }
"#;
    let r = analyze_sources(&files(annotated));
    assert!(r.clean(), "{}", r.render_text());
    assert_eq!(r.alloc_allows, 1);
    assert_eq!(r.panic_allows, 1);
    let hot = r.summaries.iter().find(|s| s.name == "hot_alloc").unwrap();
    assert_eq!(hot.allowed, 1, "suppressed alloc finding should be recorded as allowed");
    let pan = r.summaries.iter().find(|s| s.name == "panic_safety").unwrap();
    assert_eq!(pan.allowed, 1, "suppressed panic finding should be recorded as allowed");
    // the same sources without the annotations DO flag
    let stripped: String = annotated
        .lines()
        .filter(|l| !l.contains("xtask: allow"))
        .collect::<Vec<_>>()
        .join("\n");
    let r2 = analyze_sources(&files(&stripped));
    assert!(!r2.clean(), "stripping the allows must surface both findings");
    assert_eq!(r2.findings.len(), 2);
}

#[test]
fn the_crate_itself_analyzes_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let r = sada::analysis::analyze_crate(&src).expect("crate sources readable");
    assert!(r.clean(), "crate must be violation-free:\n{}", r.render_text());
    // sanity: this really was a whole-crate run, not an empty walk
    assert!(r.functions > 500, "only {} functions parsed", r.functions);
    let hot = r.summaries.iter().find(|s| s.name == "hot_alloc").unwrap();
    assert!(hot.meta > 100, "hot cone suspiciously small: {}", hot.meta);
    let pairing = r.summaries.iter().find(|s| s.name == "into_pairing").unwrap();
    assert!(pairing.meta >= 30, "expected 30+ wrapper/_into pairs, saw {}", pairing.meta);
    assert!(r.alloc_allows > 0 && r.panic_allows > 0, "annotations should be counted");
}
