//! Integration: rust runtime must reproduce the python-side goldens through
//! the compiled artifacts (same HLO, same numbers), and the rust schedule
//! must match the python abar table bit-for-bit (within f64 rounding).
//!
//! Skipped gracefully when artifacts/ has not been built.

use sada::runtime::{ModelArgs, ModelBackend, Runtime};
use sada::solvers::Schedule;
use sada::tensor::Tensor;
use sada::util::npy;

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn schedule_matches_python_abar() {
    let Some(dir) = artifacts() else { return };
    let golden = npy::read_npy(format!("{dir}/goldens/abar.npy")).expect("abar golden");
    let s = Schedule::default_ddpm();
    assert_eq!(golden.data.len(), s.abar.len());
    for (i, (g, r)) in golden.data.iter().zip(&s.abar).enumerate() {
        assert!(
            (*g as f64 - r).abs() < 1e-6,
            "abar[{i}]: python {g} vs rust {r}"
        );
    }
}

fn replay_golden(model: &str) {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let x = npy::read_npy_tensor(format!("{dir}/goldens/{model}_x.npy")).unwrap();
    let cond = npy::read_npy_tensor(format!("{dir}/goldens/{model}_cond.npy")).unwrap();
    let want = npy::read_npy_tensor(format!("{dir}/goldens/{model}_out.npy")).unwrap();
    let backend = rt.model_backend(model).unwrap();
    let out = backend
        .run(
            "full",
            &ModelArgs {
                x: Some(x),
                t: 0.5,
                cond: Some(cond),
                gs: 3.0,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.out.shape(), want.shape());
    let mut max_err = 0.0f32;
    for (a, b) in out.out.data().iter().zip(want.data()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 1e-3,
        "{model}: max |rust - python| = {max_err} (HLO replay mismatch)"
    );
}

#[test]
fn sd2_golden_replay() {
    replay_golden("sd2_tiny");
}

#[test]
fn flux_golden_replay() {
    replay_golden("flux_tiny");
}

/// Golden-replay determinism across the engine pool: the same request set
/// submitted to a 1-worker and a 4-worker coordinator must produce
/// byte-identical images per request id (the pool adds concurrency, never
/// nondeterminism).
#[test]
fn serving_outputs_bit_identical_across_worker_counts() {
    let Some(dir) = artifacts() else { return };
    use sada::coordinator::request::RequestId;
    use sada::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
    use sada::solvers::SolverKind;
    use sada::workload::PromptBank;
    use std::collections::BTreeMap;
    use std::sync::mpsc;
    use std::time::Instant;

    let run = |workers: usize| -> BTreeMap<u64, Vec<f32>> {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: dir.into(),
            models: vec!["sd2_tiny".into()],
            solver: SolverKind::DpmPP,
            batch_buckets: vec![2, 4, 8],
            max_wait_ms: 400.0,
            queue_cap: 64,
            n_workers: workers,
            ..Default::default()
        })
        .unwrap();
        let bank = PromptBank::load_or_synthetic(std::path::Path::new(dir), 32);
        let (tx, rx) = mpsc::channel();
        // 8 requests of one class (fills the largest bucket exactly) plus 4
        // of a second class (flushed as one batch at its deadline): batch
        // composition is identical for every pool size, so any output drift
        // can only come from the workers themselves
        for i in 0..12u64 {
            let steps = if i < 8 { 10 } else { 8 };
            coord
                .submit(ServeRequest {
                    id: RequestId(i),
                    model: "sd2_tiny".into(),
                    cond: bank.get(i as usize).clone(),
                    seed: bank.seed_for(i as usize),
                    steps,
                    guidance: 3.0,
                    accel: "sada".into(),
                    slo_ms: None,
                    variant_hint: None,
                    step_budget: None,
                    submitted_at: Instant::now(),
                    reply: tx.clone(),
                })
                .unwrap();
        }
        drop(tx);
        let mut out = BTreeMap::new();
        while let Ok(resp) = rx.recv() {
            out.insert(resp.id.0, resp.image.data().to_vec());
        }
        coord.shutdown().unwrap();
        out
    };

    let single = run(1);
    let quad = run(4);
    assert_eq!(single.len(), 12);
    assert_eq!(quad.len(), 12);
    for (id, img) in &single {
        assert_eq!(
            Some(img),
            quad.get(id),
            "request {id}: image differs between 1- and 4-worker pools"
        );
    }
}

#[test]
fn manifest_lists_all_variant_files() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    for (mname, m) in &rt.manifest.models {
        for (vname, v) in &m.variants {
            let p = std::path::Path::new(dir).join(&v.file);
            assert!(p.exists(), "{mname}/{vname}: missing {p:?}");
            assert!(!v.inputs.is_empty(), "{mname}/{vname}: empty inputs");
            assert!(!v.outputs.is_empty(), "{mname}/{vname}: empty outputs");
        }
    }
}

#[test]
fn deep_feature_and_caches_are_nonzero() {
    // regression for the elided-constants bug: a zero-weight artifact
    // produces all-zero outputs; real trained weights must not.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let backend = rt.model_backend("sd2_tiny").unwrap();
    let mut rng = sada::rng::Rng::new(3);
    let out = backend
        .run(
            "full",
            &ModelArgs {
                x: Some(Tensor::from_rng(&mut rng, &[1, 16, 16, 3])),
                t: 0.7,
                cond: Some(Tensor::from_rng(&mut rng, &[1, 32])),
                gs: 2.0,
                ..Default::default()
            },
        )
        .unwrap();
    assert!(sada::tensor::ops::norm2(&out.out) > 1e-3, "eps output ~ 0");
    assert!(
        sada::tensor::ops::norm2(out.caches.as_ref().unwrap()) > 1e-3,
        "caches ~ 0"
    );
    assert!(
        sada::tensor::ops::norm2(out.deep.as_ref().unwrap()) > 1e-3,
        "deep ~ 0"
    );
}
