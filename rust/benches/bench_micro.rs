//! Micro benchmarks of the L3 hot path (hand-rolled harness; the criterion
//! crate is unavailable offline). Each entry reports ns/op over enough
//! iterations for a stable mean. These are the SSPerf instrumentation:
//! all host-side per-step costs must stay far below one model execution
//! (~2.5 ms on this testbed).
//!
//! Key results (ns/op plus the lane-engine steps/s and per-step arena
//! counters) are stamped into the `micro` section of `BENCH_serving.json`
//! so the zero-copy hot path's trajectory is diffable across PRs.

use std::time::Instant;

use sada::pipeline::{Accelerator, GenRequest, NoAccel, Pipeline};
use sada::report::BenchJson;
use sada::rng::Rng;
use sada::runtime::mock::GmBackend;
use sada::runtime::ModelBackend;
use sada::sada::{multistep::X0Buffer, stepwise, Sada};
use sada::solvers::{ode, Schedule, SolverKind};
use sada::tensor::arena::TensorArena;
use sada::tensor::{ops, view, Tensor};
use sada::util::json::Json;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.min(100) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<42} {per:>12.0} ns/op   ({iters} iters)");
    per
}

fn main() {
    let mut rng = Rng::new(1);
    let shape = [1usize, 16, 16, 3];
    let x = Tensor::from_rng(&mut rng, &shape);
    let y1 = Tensor::from_rng(&mut rng, &shape);
    let y2 = Tensor::from_rng(&mut rng, &shape);
    let y3 = Tensor::from_rng(&mut rng, &shape);
    let schedule = Schedule::default_ddpm();
    let mut micro: Vec<(String, Json)> = Vec::new();
    let record = |k: &str, ns: f64, micro: &mut Vec<(String, Json)>| {
        micro.push((k.to_string(), Json::num(ns)));
    };

    println!("== bench_micro: L3 per-step host costs (16x16x3 latents) ==");
    bench("am3 extrapolation (Thm 3.5)", 200_000, || {
        let _ = stepwise::am3(&x, &y1, &y2, &y3, 0.02);
    });
    bench("fdm3 extrapolation", 200_000, || {
        let _ = stepwise::fdm3(&x, &y1, &y2);
    });
    bench("criterion dot + d2y (Crit 3.4)", 200_000, || {
        let d2 = stepwise::d2y(&y1, &y2, &y3);
        let _ = ops::dot(&x, &d2) < 0.0;
    });
    bench("token scores (64 tokens)", 100_000, || {
        let _ = sada::sada::criterion::token_scores(&x, &y1, 16, 16, 3, 2);
    });
    bench("ode gradient y = c1 x + c2 eps", 200_000, || {
        let _ = ode::gradient_eps(&schedule, 500, &x, &y1);
    });

    // allocating vs in-place lincombs: the solver step loop reuses scratch
    // buffers via the _into variants — this pair shows the win
    bench("lincomb3 (allocating)", 200_000, || {
        let _ = ops::lincomb3(1.0, &x, -2.0, &y1, 1.0, &y2);
    });
    let mut buf = Tensor::zeros(&shape);
    bench("lincomb3_into (buffer reuse)", 200_000, || {
        ops::lincomb3_into(1.0, &x, -2.0, &y1, 1.0, &y2, &mut buf);
    });
    bench("lincomb4 (allocating)", 200_000, || {
        let _ = ops::lincomb4(1.0, &x, -0.8, &y1, -0.8, &y2, 0.6, &y3);
    });
    bench("lincomb4_into (buffer reuse)", 200_000, || {
        ops::lincomb4_into(1.0, &x, -0.8, &y1, -0.8, &y2, 0.6, &y3, &mut buf);
    });

    // lane-engine gather/scatter: the allocating stack/unstack pair vs the
    // zero-copy row views writing into a reused bucket buffer
    let ns = bench("stack+unstack rows (allocating, 4 lanes)", 50_000, || {
        let s = ops::stack_rows(&[&x, &y1, &y2, &y3]);
        let _ = ops::unstack_rows(&s);
    });
    record("stack_unstack_ns", ns, &mut micro);
    {
        let mut bucket = Tensor::zeros(&[4, 16, 16, 3]);
        let mut outs = [
            Tensor::zeros(&shape),
            Tensor::zeros(&shape),
            Tensor::zeros(&shape),
            Tensor::zeros(&shape),
        ];
        let ns = bench("gather_into+scatter_from (views, 4 lanes)", 50_000, || {
            ops::gather_into(&[&x, &y1, &y2, &y3], &mut bucket);
            ops::scatter_from(&bucket, &mut outs);
        });
        record("gather_scatter_views_ns", ns, &mut micro);
        // per-row scatter (the lane engine's form) costs the same bytes
        let ns = bench("copy_from_row scatter (4 lanes)", 50_000, || {
            for (k, o) in outs.iter_mut().enumerate() {
                view::copy_from_row(o, &bucket, k);
            }
        });
        record("row_scatter_ns", ns, &mut micro);
    }

    // arena checkout/release vs a fresh zeroed allocation per step
    let ns = bench("Tensor::zeros [4,16,16,3] (allocating)", 100_000, || {
        let _ = Tensor::zeros(&[4, 16, 16, 3]);
    });
    record("alloc_zeros_ns", ns, &mut micro);
    {
        let arena = TensorArena::new();
        let ns = bench("arena checkout+release [4,16,16,3]", 100_000, || {
            let t = arena.checkout(&[4, 16, 16, 3]);
            arena.release(t);
        });
        record("arena_roundtrip_ns", ns, &mut micro);
    }

    bench("lagrange reconstruct (4 nodes)", 100_000, || {
        let mut buf = X0Buffer::new(4, 1e-9);
        for (i, t) in [0.9, 0.8, 0.7, 0.6].iter().enumerate() {
            let _ = i;
            buf.push(*t, x.clone());
        }
        let _ = buf.reconstruct(0.55);
    });
    let ns = bench("dpm++ solver step (allocating)", 100_000, || {
        let mut s = sada::solvers::DpmPP2M::new(schedule.clone(), 50);
        use sada::solvers::Solver;
        let _ = s.step(&x, &y1, 10);
    });
    record("solver_step_alloc_ns", ns, &mut micro);
    {
        // pooled solver step: warm scratch + step_into a reused buffer —
        // the shape of the lane engine's steady state
        use sada::solvers::Solver;
        let mut warm = sada::solvers::DpmPP2M::new(schedule.clone(), 50);
        let mut out = Tensor::zeros(&shape);
        warm.step_into(&x, &y1, 10, &mut out);
        let ns = bench("dpm++ solver step_into (pooled)", 100_000, || {
            warm.step_into(&x, &y1, 11, &mut out);
        });
        record("solver_step_into_ns", ns, &mut micro);
    }

    let lp = sada::metrics::LpipsRc::new(3);
    bench("lpips-rc distance (16x16x3)", 2_000, || {
        let _ = lp.distance(&x, &y1);
    });
    let fid = sada::metrics::FidRc::new(3);
    bench("fid-rc feature extraction", 2_000, || {
        let _ = fid.features(&x);
    });

    // batcher throughput
    use sada::coordinator::DynamicBatcher;
    bench("batcher push+poll (8 pending)", 50_000, || {
        let mut b = DynamicBatcher::new(vec![2, 4, 8], 10.0);
        for i in 0..8u64 {
            let (tx, _rx) = std::sync::mpsc::channel();
            b.push(
                0.0,
                sada::coordinator::ServeRequest {
                    id: sada::coordinator::request::RequestId(i),
                    model: "m".into(),
                    cond: Tensor::zeros(&[1, 4]),
                    seed: i,
                    steps: 50,
                    guidance: 3.0,
                    accel: "sada".into(),
                    slo_ms: None,
                    variant_hint: None,
                    step_budget: None,
                    submitted_at: std::time::Instant::now(),
                    reply: tx,
                },
            );
        }
        let _ = b.poll(1.0);
    });

    // end-to-end lane-engine throughput on the analytic GM backend:
    // steps/s at batch 8 plus the per-step arena counters — the headline
    // numbers for the zero-copy hot path, tracked across PRs
    {
        let backend = GmBackend::with_batch_buckets(3, &[2, 4, 8]);
        let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
        let steps = 25usize;
        let batch = 8usize;
        let mut prng = Rng::new(42);
        let reqs: Vec<GenRequest> = (0..batch)
            .map(|_| GenRequest {
                cond: Tensor::from_rng(&mut prng, &[1, 32]),
                seed: prng.below(100_000),
                guidance: 3.0,
                steps,
                edge: None,
            })
            .collect();
        for (accel_name, proto) in [
            ("baseline", Box::new(NoAccel) as Box<dyn Accelerator>),
            (
                "sada",
                Box::new(Sada::with_default(backend.info(), steps)) as Box<dyn Accelerator>,
            ),
        ] {
            // warm pools, then measure
            pipe.generate_lanes(&reqs, proto.as_ref()).expect("lane warmup");
            let before = pipe.arena_stats();
            let t0 = Instant::now();
            let rounds = 20usize;
            for _ in 0..rounds {
                pipe.generate_lanes(&reqs, proto.as_ref()).expect("lane bench");
            }
            let wall_s = t0.elapsed().as_secs_f64();
            let after = pipe.arena_stats();
            let total_steps = (rounds * steps * batch) as f64;
            let steps_per_s = total_steps / wall_s.max(1e-9);
            let misses = (after.misses - before.misses) as f64;
            let checkouts = (after.checkouts - before.checkouts).max(1) as f64;
            println!(
                "lane engine b{batch} ({accel_name:<8})  {steps_per_s:>12.0} steps/s   \
                 arena hit-rate {:.4}  allocs/step {:.5}",
                1.0 - misses / checkouts,
                misses / total_steps,
            );
            micro.push((format!("lanes_b8_{accel_name}_steps_per_s"), Json::num(steps_per_s)));
            micro.push((
                format!("lanes_b8_{accel_name}_arena_allocs_per_step"),
                Json::num(misses / total_steps),
            ));
        }
    }

    let mut bench_json = BenchJson::open_default();
    let entries: Vec<(&str, Json)> = micro.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    bench_json.set_section("micro", Json::obj(entries));
    bench_json.save_or_warn();

    // end-to-end PJRT execution if artifacts are present
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use sada::runtime::{ModelArgs, Runtime};
        let rt = Runtime::open("artifacts").expect("runtime");
        rt.preload_model("sd2_tiny").expect("preload");
        let backend = rt.model_backend("sd2_tiny").unwrap();
        let args = ModelArgs {
            x: Some(Tensor::zeros(&[1, 16, 16, 3])),
            t: 0.5,
            cond: Some(Tensor::zeros(&[1, 32])),
            gs: 3.0,
            ..Default::default()
        };
        bench("PJRT execute sd2_tiny/full", 200, || {
            let _ = backend.run("full", &args).unwrap();
        });
        let prune_args = ModelArgs {
            keep_idx: Some(std::sync::Arc::new(sada::runtime::KeepMask {
                variant: "prune50".into(),
                keep_idx: (0..32).collect(),
            })),
            caches: Some(Tensor::zeros(&[5, 2, 64, 64])),
            ..args.clone()
        };
        bench("PJRT execute sd2_tiny/prune50", 200, || {
            let _ = backend.run("prune50", &prune_args).unwrap();
        });
        let shallow_args = ModelArgs {
            deep: Some(Tensor::zeros(&[2, 64, 64])),
            ..args.clone()
        };
        bench("PJRT execute sd2_tiny/shallow", 200, || {
            let _ = backend.run("shallow", &shallow_args).unwrap();
        });
    } else {
        println!("(artifacts/ missing: skipping PJRT execution benches)");
    }
}
