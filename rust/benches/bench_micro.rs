//! Micro benchmarks of the L3 hot path (hand-rolled harness; the criterion
//! crate is unavailable offline). Each entry reports ns/op over enough
//! iterations for a stable mean. These are the SSPerf instrumentation:
//! all host-side per-step costs must stay far below one model execution
//! (~2.5 ms on this testbed).

use std::time::Instant;

use sada::rng::Rng;
use sada::sada::{multistep::X0Buffer, stepwise};
use sada::solvers::{ode, Schedule};
use sada::tensor::{ops, Tensor};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(100) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<42} {per:>12.0} ns/op   ({iters} iters)");
}

fn main() {
    let mut rng = Rng::new(1);
    let shape = [1usize, 16, 16, 3];
    let x = Tensor::from_rng(&mut rng, &shape);
    let y1 = Tensor::from_rng(&mut rng, &shape);
    let y2 = Tensor::from_rng(&mut rng, &shape);
    let y3 = Tensor::from_rng(&mut rng, &shape);
    let schedule = Schedule::default_ddpm();

    println!("== bench_micro: L3 per-step host costs (16x16x3 latents) ==");
    bench("am3 extrapolation (Thm 3.5)", 200_000, || {
        let _ = stepwise::am3(&x, &y1, &y2, &y3, 0.02);
    });
    bench("fdm3 extrapolation", 200_000, || {
        let _ = stepwise::fdm3(&x, &y1, &y2);
    });
    bench("criterion dot + d2y (Crit 3.4)", 200_000, || {
        let d2 = stepwise::d2y(&y1, &y2, &y3);
        let _ = ops::dot(&x, &d2) < 0.0;
    });
    bench("token scores (64 tokens)", 100_000, || {
        let _ = sada::sada::criterion::token_scores(&x, &y1, 16, 16, 3, 2);
    });
    bench("ode gradient y = c1 x + c2 eps", 200_000, || {
        let _ = ode::gradient_eps(&schedule, 500, &x, &y1);
    });

    // allocating vs in-place lincombs: the solver step loop now reuses
    // scratch buffers via the _into variants — this pair shows the win
    bench("lincomb3 (allocating)", 200_000, || {
        let _ = ops::lincomb3(1.0, &x, -2.0, &y1, 1.0, &y2);
    });
    let mut buf = Tensor::zeros(&shape);
    bench("lincomb3_into (buffer reuse)", 200_000, || {
        ops::lincomb3_into(1.0, &x, -2.0, &y1, 1.0, &y2, &mut buf);
    });
    bench("lincomb4 (allocating)", 200_000, || {
        let _ = ops::lincomb4(1.0, &x, -0.8, &y1, -0.8, &y2, 0.6, &y3);
    });
    bench("lincomb4_into (buffer reuse)", 200_000, || {
        ops::lincomb4_into(1.0, &x, -0.8, &y1, -0.8, &y2, 0.6, &y3, &mut buf);
    });
    // lane engine gather/scatter primitives
    bench("lane gather+scatter (4 lanes)", 50_000, || {
        let s = ops::stack_rows(&[&x, &y1, &y2, &y3]);
        let _ = ops::unstack_rows(&s);
    });
    bench("lagrange reconstruct (4 nodes)", 100_000, || {
        let mut buf = X0Buffer::new(4, 1e-9);
        for (i, t) in [0.9, 0.8, 0.7, 0.6].iter().enumerate() {
            let _ = i;
            buf.push(*t, x.clone());
        }
        let _ = buf.reconstruct(0.55);
    });
    bench("dpm++ solver step", 100_000, || {
        let mut s = sada::solvers::DpmPP2M::new(schedule.clone(), 50);
        use sada::solvers::Solver;
        let _ = s.step(&x, &y1, 10);
    });
    {
        // warm solver: the 2M blend reuses its scratch buffer across steps
        use sada::solvers::Solver;
        let mut warm = sada::solvers::DpmPP2M::new(schedule.clone(), 50);
        let _ = warm.step(&x, &y1, 10);
        bench("dpm++ solver step (warm scratch)", 100_000, || {
            let _ = warm.step(&x, &y1, 11);
        });
    }

    let lp = sada::metrics::LpipsRc::new(3);
    bench("lpips-rc distance (16x16x3)", 2_000, || {
        let _ = lp.distance(&x, &y1);
    });
    let fid = sada::metrics::FidRc::new(3);
    bench("fid-rc feature extraction", 2_000, || {
        let _ = fid.features(&x);
    });

    // batcher throughput
    use sada::coordinator::DynamicBatcher;
    bench("batcher push+poll (8 pending)", 50_000, || {
        let mut b = DynamicBatcher::new(vec![2, 4, 8], 10.0);
        for i in 0..8u64 {
            let (tx, _rx) = std::sync::mpsc::channel();
            b.push(
                0.0,
                sada::coordinator::ServeRequest {
                    id: sada::coordinator::request::RequestId(i),
                    model: "m".into(),
                    cond: Tensor::zeros(&[1, 4]),
                    seed: i,
                    steps: 50,
                    guidance: 3.0,
                    accel: "sada".into(),
                    submitted_at: std::time::Instant::now(),
                    reply: tx,
                },
            );
        }
        let _ = b.poll(1.0);
    });

    // end-to-end PJRT execution if artifacts are present
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use sada::runtime::{ModelArgs, ModelBackend, Runtime};
        let rt = Runtime::open("artifacts").expect("runtime");
        rt.preload_model("sd2_tiny").expect("preload");
        let backend = rt.model_backend("sd2_tiny").unwrap();
        let args = ModelArgs {
            x: Some(Tensor::zeros(&[1, 16, 16, 3])),
            t: 0.5,
            cond: Some(Tensor::zeros(&[1, 32])),
            gs: 3.0,
            ..Default::default()
        };
        bench("PJRT execute sd2_tiny/full", 200, || {
            let _ = backend.run("full", &args).unwrap();
        });
        let prune_args = ModelArgs {
            keep_idx: Some((0..32).collect()),
            caches: Some(Tensor::zeros(&[5, 2, 64, 64])),
            ..args.clone()
        };
        bench("PJRT execute sd2_tiny/prune50", 200, || {
            let _ = backend.run("prune50", &prune_args).unwrap();
        });
        let shallow_args = ModelArgs {
            deep: Some(Tensor::zeros(&[2, 64, 64])),
            ..args.clone()
        };
        bench("PJRT execute sd2_tiny/shallow", 200, || {
            let _ = backend.run("shallow", &shallow_args).unwrap();
        });
    } else {
        println!("(artifacts/ missing: skipping PJRT execution benches)");
    }
}
