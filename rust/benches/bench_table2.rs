//! Timing-only regeneration of Table 2's speedup column: SADA latency
//! across step budgets {50, 25, 15} on sd2/sdxl x {dpmpp, euler}, plus the
//! serving-scaling dimension: coordinator throughput at {1, 2, 4} engine
//! workers on a multi-request trace.

use sada::pipeline::{GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts/ missing: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::open("artifacts")?;
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), rt.manifest.cond_dim);
    let n = 4;
    println!("== bench_table2: SADA few-step latency ({n} prompts) ==");
    println!("{:<11} {:<7} {:>6} {:>11} {:>9} {:>8}", "model", "solver", "steps", "ms/sample", "speedup", "NFE");
    for model in ["sd2_tiny", "sdxl_tiny"] {
        rt.preload_model(model)?;
        let backend = rt.model_backend(model)?;
        for solver in [SolverKind::DpmPP, SolverKind::Euler] {
            let pipe =
                Pipeline::with_schedule(&backend, solver, rt.manifest.schedule.to_schedule());
            for steps in [50usize, 25, 15] {
                let mut base_ms = 0.0;
                let mut sada_ms = 0.0;
                let mut nfe = 0;
                for p in 0..n {
                    let req = GenRequest {
                        cond: bank.get(p).clone(),
                        seed: bank.seed_for(p),
                        guidance: 3.0,
                        steps,
                        edge: None,
                    };
                    base_ms += pipe.generate(&req, &mut NoAccel)?.stats.wall_ms;
                    let mut accel = Sada::with_default(backend.info(), steps);
                    let r = pipe.generate(&req, &mut accel)?;
                    sada_ms += r.stats.wall_ms;
                    nfe += r.stats.nfe;
                }
                println!(
                    "{model:<11} {:<7} {steps:>6} {:>11.1} {:>8.2}x {:>5.1}/{steps}",
                    solver.name(),
                    sada_ms / n as f64,
                    base_ms / sada_ms,
                    nfe as f64 / n as f64,
                );
            }
        }
    }

    // scaling dimension: the same trace through 1, 2 and 4 engine workers
    // (coordinator pool); throughput must not regress with workers
    println!();
    sada::exp::serving::run_scaling("artifacts", "sd2_tiny", 16, 50.0, 15, &[1, 2, 4], false)?;

    // per-lane vs lockstep: per-request NFE and skip-rate divergence on
    // divergent-trajectory batches, including sizes (3, 5) with no exact
    // compiled bucket
    println!();
    sada::exp::serving::run_lane_sweep("artifacts", "sd2_tiny", 25, &[2, 3, 5, 8])?;

    // skip-plan cache: hit rate + NFE cut of speculative warm-start replay
    // on a repeated-prompt trace (also refreshes BENCH_serving.json)
    println!();
    sada::exp::serving::run_plancache_sweep("artifacts", "sd2_tiny", 25, 32, 4)?;

    // continuous batching: step-granularity admission vs run-to-completion
    // on a saturated heterogeneous-steps queue + SLO attainment through a
    // continuous-mode coordinator (self-checks occupancy >= 0.95 and the
    // strict engine-step win; stamps the `continuous` BENCH section)
    println!();
    sada::exp::serving::run_continuous_sweep("artifacts", "sd2_tiny", 48, 4, 2)?;

    // degraded-variant buckets: batched prune{k}_b{n}/shallow_b{n} launches
    // vs batch-1 singles on a prune-heavy replay trace (mock-backed so the
    // launch counter is exact; self-checks bit-identity and the >= 2x
    // launch cut; stamps the `degraded_buckets` BENCH section)
    println!();
    sada::exp::serving::run_degraded_buckets_sweep(8, 24)?;

    // slack-aware scheduling: FIFO-steal vs slack-ranked vs slack+preempt
    // arms over a saturated cache-hot/cold queue with calibrated bimodal
    // SLOs (self-checks the strict attainment win, >= 1 preempt-and-resume
    // and bit-identity to solo runs; stamps the `scheduler` BENCH section)
    println!();
    sada::exp::serving::run_scheduler_sweep("artifacts", "sd2_tiny", 16, 4)?;
    Ok(())
}
