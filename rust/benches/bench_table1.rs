//! Timing-only regeneration of Table 1's speedup column: end-to-end
//! per-sample latency for each method on each (model, solver) cell.
//! Quality metrics come from `sada-serve table1`; this bench isolates the
//! wall-clock claim with a smaller prompt set for quick iteration.

use sada::baselines::{AdaptiveDiffusion, DeepCache, TeaCache};
use sada::pipeline::{Accelerator, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts/ missing: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::open("artifacts")?;
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), rt.manifest.cond_dim);
    let steps = 50;
    let n = 4;
    println!("== bench_table1: end-to-end latency per method ({n} prompts, {steps} steps) ==");
    println!("{:<11} {:<7} {:<18} {:>10} {:>9}", "model", "solver", "method", "ms/sample", "speedup");

    let cells: [(&str, SolverKind); 5] = [
        ("sd2_tiny", SolverKind::DpmPP),
        ("sd2_tiny", SolverKind::Euler),
        ("sdxl_tiny", SolverKind::DpmPP),
        ("sdxl_tiny", SolverKind::Euler),
        ("flux_tiny", SolverKind::Flow),
    ];
    for (model, solver) in cells {
        rt.preload_model(model)?;
        let backend = rt.model_backend(model)?;
        let pipe =
            Pipeline::with_schedule(&backend, solver, rt.manifest.schedule.to_schedule());
        let run = |accel: &mut dyn Accelerator| -> anyhow::Result<f64> {
            let mut total = 0.0;
            for p in 0..n {
                let req = GenRequest {
                    cond: bank.get(p).clone(),
                    seed: bank.seed_for(p),
                    guidance: 3.0,
                    steps,
                    edge: None,
                };
                total += pipe.generate(&req, accel)?.stats.wall_ms;
            }
            Ok(total / n as f64)
        };
        let base_ms = run(&mut NoAccel)?;
        println!("{model:<11} {:<7} {:<18} {base_ms:>10.1} {:>8.2}x", solver.name(), "baseline", 1.0);
        let mut methods: Vec<(&str, Box<dyn Accelerator>)> = if model == "flux_tiny" {
            vec![
                ("teacache", Box::new(TeaCache::default())),
                ("sada", Box::new(Sada::with_default(backend.info(), steps))),
            ]
        } else {
            vec![
                ("deepcache", Box::new(DeepCache::default())),
                ("adaptive", Box::new(AdaptiveDiffusion::default())),
                ("sada", Box::new(Sada::with_default(backend.info(), steps))),
            ]
        };
        for (name, accel) in methods.iter_mut() {
            let ms = run(accel.as_mut())?;
            println!(
                "{model:<11} {:<7} {name:<18} {ms:>10.1} {:>8.2}x",
                solver.name(),
                base_ms / ms
            );
        }
    }
    Ok(())
}
