//! Analyzer driver: `cargo run -p xtask -- analyze [--src DIR] [--json PATH]`.
//!
//! Includes the analyzer sources directly (`#[path]`) so the binary builds
//! whether or not the main crate's workspace manifest is present; the same
//! modules are also exported as `sada::analysis` for the in-crate tests.
//!
//! Exit codes: 0 = clean, 1 = invariant violations found, 2 = usage/IO error.

#[path = "../../src/analysis/mod.rs"]
mod analysis;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: xtask analyze [--src DIR] [--json PATH]");
    eprintln!("  --src DIR    crate source root (default: ../src relative to xtask)");
    eprintln!("  --json PATH  where to write the machine-readable report");
    eprintln!("               (default: <repo>/ANALYSIS.json)");
    ExitCode::from(2)
}

fn default_src() -> PathBuf {
    // xtask lives at rust/xtask; the crate sources at rust/src
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../src")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("analyze") {
        return usage();
    }
    let mut src = default_src();
    let mut json_path = src.join("../../ANALYSIS.json");
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--src" => match it.next() {
                Some(v) => src = PathBuf::from(v),
                None => return usage(),
            },
            "--json" => match it.next() {
                Some(v) => json_path = PathBuf::from(v),
                None => return usage(),
            },
            _ => return usage(),
        }
    }
    let report = match analysis::analyze_crate(&src) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask analyze: cannot read {}: {e}", src.display());
            return ExitCode::from(2);
        }
    };
    print!("{}", report.render_text());
    let json = report.to_json(&src.display().to_string());
    if let Err(e) = std::fs::write(&json_path, json) {
        eprintln!("xtask analyze: cannot write {}: {e}", json_path.display());
        return ExitCode::from(2);
    }
    println!("wrote {}", json_path.display());
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
