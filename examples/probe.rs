//! Calibration probe: sweep baseline-method hyperparameters on the real
//! artifacts to locate paper-shaped operating points (used to pin the
//! defaults recorded in EXPERIMENTS.md "Method calibration").
//!
//! ```bash
//! cargo run --release --example probe
//! ```

use sada::baselines::TeaCache;
use sada::metrics::psnr;
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.preload_model("flux_tiny")?;
    let backend = rt.model_backend("flux_tiny")?;
    let pipe = Pipeline::new(&backend, SolverKind::Flow);
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), 32);
    println!("== TeaCache tau sweep on flux_tiny (50 steps, 4 prompts) ==");
    for tau in [0.02f64, 0.05, 0.1, 0.2, 0.4] {
        let (mut ps, mut nfe, mut bms, mut mms) = (0.0, 0usize, 0.0, 0.0);
        for p in 0..4 {
            let req = GenRequest {
                cond: bank.get(p).clone(),
                seed: bank.seed_for(p),
                guidance: 3.0,
                steps: 50,
                edge: None,
            };
            let base = pipe.generate(&req, &mut NoAccel)?;
            let mut tc = TeaCache::new(tau);
            let r = pipe.generate(&req, &mut tc)?;
            ps += psnr(&decode::finalize(&base.image), &decode::finalize(&r.image));
            nfe += r.stats.nfe;
            bms += base.stats.wall_ms;
            mms += r.stats.wall_ms;
        }
        println!(
            "tau={tau:<5} psnr={:.2} nfe={:.1}/50 speedup={:.2}x",
            ps / 4.0,
            nfe as f64 / 4.0,
            bms / mms
        );
    }
    println!("== SADA reference point on flux_tiny ==");
    let (mut ps, mut nfe, mut bms, mut mms) = (0.0, 0usize, 0.0, 0.0);
    for p in 0..4 {
        let req = GenRequest {
            cond: bank.get(p).clone(),
            seed: bank.seed_for(p),
            guidance: 3.0,
            steps: 50,
            edge: None,
        };
        let base = pipe.generate(&req, &mut NoAccel)?;
        let mut s = Sada::with_default(backend.info(), 50);
        let r = pipe.generate(&req, &mut s)?;
        ps += psnr(&decode::finalize(&base.image), &decode::finalize(&r.image));
        nfe += r.stats.nfe;
        bms += base.stats.wall_ms;
        mms += r.stats.wall_ms;
    }
    println!(
        "sada  psnr={:.2} nfe={:.1}/50 speedup={:.2}x",
        ps / 4.0,
        nfe as f64 / 4.0,
        bms / mms
    );
    Ok(())
}
