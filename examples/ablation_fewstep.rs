//! Few-step ablation example (paper Table 2 shape): SADA under shrinking
//! step budgets, showing the speedup/fidelity scaling.
//!
//! ```bash
//! make artifacts && cargo run --release --example ablation_fewstep
//! ```

use sada::metrics::{psnr, LpipsRc};
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.preload_model("sd2_tiny")?;
    let backend = rt.model_backend("sd2_tiny")?;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), rt.manifest.cond_dim);
    let lpips = LpipsRc::new(3);

    println!("steps | NFE      | speedup | PSNR  | LPIPS");
    println!("------+----------+---------+-------+------");
    for steps in [50usize, 25, 15] {
        let mut sp = 0.0;
        let mut ps = 0.0;
        let mut lp = 0.0;
        let mut nfe = 0;
        let n = 4;
        for p in 0..n {
            let req = GenRequest {
                cond: bank.get(p).clone(),
                seed: bank.seed_for(p),
                guidance: 3.0,
                steps,
                edge: None,
            };
            let base = pipe.generate(&req, &mut NoAccel)?;
            let mut accel = Sada::with_default(backend.info(), steps);
            let fast = pipe.generate(&req, &mut accel)?;
            let b = decode::finalize(&base.image);
            let f = decode::finalize(&fast.image);
            sp += base.stats.wall_ms / fast.stats.wall_ms;
            ps += psnr(&b, &f);
            lp += lpips.distance(&b, &f);
            nfe += fast.stats.nfe;
        }
        println!(
            "{steps:5} | {:4.1}/{steps:<3} | {:6.2}x | {:5.2} | {:.4}",
            nfe as f64 / n as f64,
            sp / n as f64,
            ps / n as f64,
            lp / n as f64
        );
    }
    Ok(())
}
