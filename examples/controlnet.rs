//! ControlNet-analog example: edge-conditioned generation accelerated by
//! SADA with zero pipeline modifications (paper Fig. 7).
//!
//! ```bash
//! make artifacts && cargo run --release --example controlnet
//! ```

use sada::exp::controlnet::load_edges;
use sada::metrics::{psnr, LpipsRc};
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::util::npy;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.preload_model("control_tiny")?;
    let backend = rt.model_backend("control_tiny")?;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let edges = load_edges("artifacts")?;
    // conditioning vectors exported alongside the edge maps
    let conds = npy::read_npy("artifacts/control_conds.npy")?;
    let k = conds.shape[1];
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), k);

    let lpips = LpipsRc::new(3);
    for idx in 0..3usize {
        let req = GenRequest {
            cond: sada::Tensor::new(conds.data[idx * k..(idx + 1) * k].to_vec(), &[1, k])?,
            seed: bank.seed_for(idx),
            guidance: 3.0,
            steps: 50,
            edge: Some(edges[idx].clone()),
        };
        let base = pipe.generate(&req, &mut NoAccel)?;
        let mut accel = Sada::with_default(backend.info(), req.steps);
        let fast = pipe.generate(&req, &mut accel)?;
        let b = decode::finalize(&base.image);
        let f = decode::finalize(&fast.image);
        println!(
            "edge #{idx}: speedup {:.2}x (NFE {}/{}), PSNR {:.2}, LPIPS {:.4}",
            base.stats.wall_ms / fast.stats.wall_ms,
            fast.stats.nfe,
            req.steps,
            psnr(&b, &f),
            lpips.distance(&b, &f),
        );
        println!("edge map:\n{}", decode::ascii_preview(&edges[idx], 16, 16));
        println!("SADA sample:\n{}", decode::ascii_preview(&f, 16, 16));
    }
    Ok(())
}
