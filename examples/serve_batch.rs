//! End-to-end serving driver (the mandated E2E validation example).
//!
//! Starts the coordinator (router -> dynamic batcher -> sharded engine
//! pool), sends a Poisson request stream against the sd2_tiny model, and
//! reports latency percentiles + throughput for baseline vs SADA under
//! identical load. With `workers > 0` a single pool size is used; with
//! `workers == 0` (the default) the engine pool is swept over {1, 2, 4}
//! workers so the speedup table gains its scaling dimension. Results are
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch -- [n] [rate_rps] [steps] [workers]
//! ```

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.0);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(50);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(0);
    if workers == 0 {
        sada::exp::serving::run_scaling("artifacts", "sd2_tiny", n, rate, steps, &[1, 2, 4], false)
    } else {
        sada::exp::serving::run_with_load("artifacts", "sd2_tiny", n, rate, steps, false, workers)
    }
}
