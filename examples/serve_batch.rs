//! End-to-end serving driver (the mandated E2E validation example).
//!
//! Starts the coordinator (router -> dynamic batcher -> PJRT engine), sends
//! a Poisson request stream against the sd2_tiny model, and reports
//! latency percentiles + throughput for baseline vs SADA under identical
//! load. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch -- [n] [rate_rps] [steps]
//! ```

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.0);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(50);
    sada::exp::serving::run("artifacts", "sd2_tiny", n, rate, steps)
}
