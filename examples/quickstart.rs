//! Quickstart: generate one sample with and without SADA and compare.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use sada::metrics::{psnr, LpipsRc};
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    // 1. open the artifact registry (compiled by `make artifacts`)
    let rt = Runtime::open("artifacts")?;
    rt.preload_model("sd2_tiny")?;
    let backend = rt.model_backend("sd2_tiny")?;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);

    // 2. pick a prompt from the COCO-analog bank
    let bank = PromptBank::load_or_synthetic(std::path::Path::new("artifacts"), rt.manifest.cond_dim);
    let req = GenRequest {
        cond: bank.get(7).clone(),
        seed: bank.seed_for(7),
        guidance: 3.0,
        steps: 50,
        edge: None,
    };

    // 3. baseline: 50 full model evaluations
    let base = pipe.generate(&req, &mut NoAccel)?;

    // 4. SADA: the stability criterion decides per step
    let mut sada = Sada::with_default(backend.info(), req.steps);
    let fast = pipe.generate(&req, &mut sada)?;

    let b = decode::finalize(&base.image);
    let f = decode::finalize(&fast.image);
    let lpips = LpipsRc::new(3);
    println!("baseline: NFE {}/50, {:.0} ms", base.stats.nfe, base.stats.wall_ms);
    println!(
        "SADA:     NFE {}/50, {:.0} ms  (modes: {})",
        fast.stats.nfe,
        fast.stats.wall_ms,
        fast.stats.mode_trace()
    );
    println!(
        "speedup {:.2}x | PSNR {:.2} dB | LPIPS-RC {:.4}",
        base.stats.wall_ms / fast.stats.wall_ms,
        psnr(&b, &f),
        lpips.distance(&b, &f)
    );
    println!("\nbaseline sample:\n{}", decode::ascii_preview(&b, 16, 16));
    println!("SADA sample:\n{}", decode::ascii_preview(&f, 16, 16));
    Ok(())
}
