//! MusicLDM-analog example: mel-spectrogram generation ("8-second clips")
//! accelerated by SADA (paper Fig. 6) — different modality, zero changes.
//!
//! ```bash
//! make artifacts && cargo run --release --example musicgen
//! ```

use sada::metrics::{psnr, LpipsRc};
use sada::pipeline::{decode, GenRequest, NoAccel, Pipeline};
use sada::runtime::{ModelBackend, Runtime};
use sada::sada::Sada;
use sada::solvers::SolverKind;
use sada::workload::PromptBank;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.preload_model("music_tiny")?;
    let backend = rt.model_backend("music_tiny")?;
    let pipe = Pipeline::new(&backend, SolverKind::DpmPP);
    let bank = PromptBank::load(std::path::Path::new("artifacts").join("music_prompts.npy"))
        .unwrap_or_else(|_| PromptBank::synthetic(64, rt.manifest.cond_dim, 17));
    let lpips = LpipsRc::new(1); // single-channel spectrogram LPIPS

    for idx in 0..3usize {
        let req = GenRequest {
            cond: bank.get(idx).clone(),
            seed: bank.seed_for(idx),
            guidance: 3.0,
            steps: 50,
            edge: None,
        };
        let base = pipe.generate(&req, &mut NoAccel)?;
        let mut accel = Sada::with_default(backend.info(), req.steps);
        let fast = pipe.generate(&req, &mut accel)?;
        let b = decode::finalize(&base.image);
        let f = decode::finalize(&fast.image);
        println!(
            "clip #{idx}: speedup {:.2}x (NFE {}/{}), spec-PSNR {:.2}, spec-LPIPS {:.4}",
            base.stats.wall_ms / fast.stats.wall_ms,
            fast.stats.nfe,
            req.steps,
            psnr(&b, &f),
            lpips.distance(&b, &f),
        );
        println!("spectrogram (16 mel bins x 64 frames):\n{}", decode::ascii_preview(&f, 16, 64));
    }
    Ok(())
}
